"""Staged streaming pipeline — the loader behind ``LoaderConfig.pipeline``.

The legacy worker/fetcher path treats ``dataset[i]`` as one opaque unit, so
network fetch, decode and augmentation all run on the same fetch thread:
slow CPU preprocessing blocks IO concurrency, a straggler GET parks the
CPU, and a worker's whole thread pool idles through the tail of every batch
(head-of-line blocking at the batch boundary).  This module splits the item
path into an explicit stage graph::

    sampler -> [fetch-raw | IO executor] -> bounded queue
            -> [decode -> augment | CPU executor] -> completion queue
            -> [assembler: collate] -> consumer (-> device-prefetch ring)

* **IO executor** — thread pool or asyncio event loop (``LoaderConfig.impl``)
  whose effective concurrency is an :class:`AdjustableSemaphore` gate, with
  optional hedged duplicates for straggler GETs (reusing
  :class:`~repro.core.fetcher.HedgeTracker`).
* **CPU executor** — ``decode_raw`` + ``augment_item`` on a separate gated
  executor (datasets exposing the split path; see
  :class:`repro.data.dataset.MapDataset`): a thread pool
  (``LoaderConfig.cpu_executor="thread"``, right for GIL-releasing C
  decoders) or a spawn-based worker-process pool (``"process"``, the GIL
  escape for pure-Python decoders — Appendix A.4's ceiling; requires a
  picklable dataset, persists across epochs, respawns crashed workers and
  retries only their in-flight sample).  Datasets that cannot split fall
  back to the monolithic ``__getitem__`` on the IO executor.
* **Out-of-order completion** — samples finish in whatever order storage and
  CPU allow; the assembler composes batches per ``LoaderConfig.reorder``:
  ``"strict"`` rebuilds exactly the legacy stream (same samples, same order,
  bit-identical), ``"window"`` fills each aligned group of
  ``reorder_window`` batch slots with whichever of the group's samples
  finish first, so a straggler only delays the *last* batch of its group.
* **Per-stage observability** — every sample records ``stage_fetch`` /
  ``stage_decode`` / ``stage_augment`` spans and every batch a
  ``stage_collate`` span; inter-stage queues track occupancy
  (:meth:`_PipelineIter.stage_stats`), which is how ``bench_pipeline``
  proves decode/IO overlap.
* **Per-stage tuning** — io workers, cpu workers, the outstanding sample
  window and the fetch->decode queue depth are live knobs registered with
  the loader's :class:`~repro.core.autotune.AutotuneController`
  (:func:`~repro.core.autotune.build_pipeline_knobs`).
"""
from __future__ import annotations

import asyncio
import math
import multiprocessing
import os
import pickle
import queue
import threading
import time
import weakref
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from multiprocessing.connection import wait as _mp_wait
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import shm as shm_mod
from repro.core.fetcher import (
    AdjustableSemaphore,
    aretry_transient,
    retry_transient,
)
from repro.core.sampler import BatchIndices
from repro.core.tracing import (
    BYTES_COPIED,
    SHUFFLE_ENTROPY,
    STAGE_AUGMENT,
    STAGE_COLLATE,
    STAGE_DECODE,
    STAGE_FETCH,
)


class _Sample:
    """One flattened unit of work flowing through the stage graph."""

    __slots__ = ("batch_id", "pos", "index", "raw")

    def __init__(self, batch_id: int, pos: int, index: int) -> None:
        self.batch_id = batch_id
        self.pos = pos
        self.index = index
        self.raw: Any = None


class _Failure:
    """Exception carrier routed through the completion queue."""

    __slots__ = ("exc",)

    def __init__(self, exc: BaseException) -> None:
        self.exc = exc


class _Composed:
    """Completion-queue token for a fully composed device-sharded batch
    (sharded delivery, :mod:`repro.core.delivery`).  Defined here rather
    than in the delivery module so the pipeline's hot loop can type-check
    it without importing jax."""

    __slots__ = ("batch_id",)

    def __init__(self, batch_id: int) -> None:
        self.batch_id = batch_id


class _BoundedQ:
    """FIFO whose capacity is an :class:`AdjustableSemaphore`, so queue depth
    is a live autotune knob.  ``put`` blocks while the downstream stage is
    full (polling the pipeline stop event) — that stall, propagating back to
    the IO gate, is the pipeline's backpressure.  Tracks occupancy so the
    bottleneck stage is visible (a full fetch->decode queue = CPU-bound, an
    empty one = IO-bound)."""

    def __init__(self, depth: int, stop: threading.Event) -> None:
        self._q: "queue.Queue" = queue.Queue()
        self._cap = AdjustableSemaphore(max(1, depth))
        self._stop = stop
        self._lock = threading.Lock()
        self._occ_sum = 0
        self._occ_n = 0
        self._occ_max = 0

    @property
    def depth(self) -> int:
        return self._cap.limit

    def resize(self, depth: int, hi: int) -> int:
        d = max(1, min(int(depth), hi))
        self._cap.set_limit(d)
        return d

    def _note(self) -> None:
        size = self._q.qsize()
        with self._lock:
            self._occ_sum += size
            self._occ_n += 1
            self._occ_max = max(self._occ_max, size)

    def put(self, item: Any) -> bool:
        while not self._cap.acquire(timeout=0.1):
            if self._stop.is_set():
                return False
        self._q.put(item)
        self._note()
        return True

    def get(self, timeout: float = 0.1) -> Any:
        item = self._q.get(timeout=timeout)  # queue.Empty passes through
        self._cap.release()
        self._note()
        return item

    def occupancy(self) -> Dict[str, float]:
        with self._lock:
            mean = self._occ_sum / self._occ_n if self._occ_n else 0.0
            return {
                "depth": self._cap.limit,
                "now": self._q.qsize(),
                "mean": round(mean, 2),
                "max": self._occ_max,
            }


# ---------------------------------------------------------------------------
# IO stage
# ---------------------------------------------------------------------------


class _IOStage:
    """Fetch-raw stage: a dedicated IO executor (thread pool or asyncio loop)
    gated by an :class:`AdjustableSemaphore`.

    Admission is caller-side: :meth:`submit` parks samples in a pending deque
    and ``_kick`` moves them onto the executor only when a gate permit is
    free, so idle executor threads never pile up behind the gate and a
    ``resize`` takes effect at the next admission.  The gate permit is held
    across the fetch AND the (possibly blocking) hand-off into the
    fetch->decode queue: when decode backs up, IO concurrency drains to zero
    instead of buffering unboundedly.

    Hedging (both modes, reusing :class:`HedgeTracker`): the assembler
    loop calls :meth:`hedge_scan`; any in-flight fetch older than the p95
    deadline gets one ungated duplicate — on the pool's headroom threads
    (threaded) or as an extra coroutine on the event loop (asyncio) — and
    the first completion wins via the shared ``_inflight`` pop.
    """

    def __init__(
        self,
        dataset,
        *,
        mode: str,  # "threaded" | "asyncio"
        width: int,
        hard_cap: int,
        split: bool,
        decode_q: _BoundedQ,
        done_q: "queue.Queue",
        stop: threading.Event,
        tracer,
        hedge=None,
    ) -> None:
        self.dataset = dataset
        self.mode = mode
        self.split = split
        self.decode_q = decode_q
        self.done_q = done_q
        self.stop = stop
        self.tracer = tracer
        self.hedge = hedge
        self.hard_cap = max(width, hard_cap)
        self.gate = AdjustableSemaphore(width)
        self._pending: deque = deque()
        self._lock = threading.Lock()
        # in-flight registry: id(sample) -> (sample, t0).  Doubles as the
        # first-response-wins arbiter for hedged fetches: whichever copy
        # pops the entry owns the sample; the loser finds it gone and drops
        # its result.
        self._inflight: Dict[int, Tuple[_Sample, float]] = {}
        if mode == "asyncio":
            self._loop = asyncio.new_event_loop()
            self._thread = threading.Thread(
                target=self._loop.run_forever, name="pipe-io-loop", daemon=True
            )
            self._thread.start()
            self._pool = None
        else:
            self._loop = None
            # +2 headroom threads so hedge duplicates can run while every
            # gated slot is busy with stragglers
            self._pool = ThreadPoolExecutor(
                max_workers=self.hard_cap + 2, thread_name_prefix="pipe-io"
            )

    # -- admission -----------------------------------------------------------
    def submit(self, sample: _Sample) -> None:
        with self._lock:
            self._pending.append(sample)
        self._kick()

    def _kick(self) -> None:
        while True:
            with self._lock:
                if not self._pending or not self.gate.acquire(timeout=0):
                    return
                s = self._pending.popleft()
            if self._loop is not None:
                asyncio.run_coroutine_threadsafe(self._afetch(s), self._loop)
            else:
                self._pool.submit(self._run_fetch, s)

    def resize(self, width: int) -> int:
        w = max(1, min(int(width), self.hard_cap))
        self.gate.set_limit(w)
        self._kick()  # a raised limit admits parked samples immediately
        return w

    # -- completion (first response wins when hedged) ------------------------
    def _complete(self, s: _Sample, raw: Any) -> bool:
        """Route a finished fetch downstream; returns False when the other
        copy of a hedged fetch already claimed the sample."""
        with self._lock:
            if self._inflight.pop(id(s), None) is None:
                return False
        if self.split:
            s.raw = raw
            self.decode_q.put(s)
        else:
            self.done_q.put((s, raw))  # raw IS the finished item (monolithic)
        return True

    def _fail(self, s: _Sample, exc: BaseException) -> None:
        with self._lock:
            if self._inflight.pop(id(s), None) is None:
                return  # a hedge duplicate already delivered this sample
        self.done_q.put((s, _Failure(exc)))

    # -- threaded fetch ------------------------------------------------------
    def _fetch_value(self, s: _Sample) -> Any:
        if self.split:
            return retry_transient(self.dataset.get_raw, s.index)
        return retry_transient(self.dataset.__getitem__, s.index)

    def _run_fetch(self, s: _Sample) -> None:
        t0 = time.monotonic()
        with self._lock:
            self._inflight[id(s)] = (s, t0)
        try:
            raw = self._fetch_value(s)
            t1 = time.monotonic()
            self.tracer.record(STAGE_FETCH, t0, t1, index=s.index,
                               batch_id=s.batch_id)
            if self.hedge is not None:
                self.hedge.observe(t1 - t0)
            self._complete(s, raw)
        except BaseException as e:
            self._fail(s, e)
        finally:
            self.gate.release()
            self._kick()

    def _run_hedge(self, s: _Sample) -> None:
        """Ungated duplicate of a straggling fetch; first completion wins."""
        t0 = time.monotonic()
        try:
            raw = self._fetch_value(s)
            self.tracer.record(STAGE_FETCH, t0, time.monotonic(),
                               index=s.index, batch_id=s.batch_id, hedge=True)
            if self._complete(s, raw) and self.hedge is not None:
                self.hedge.hedges_won += 1
        except BaseException:
            pass  # the original is still in flight; let it decide the outcome

    def hedge_scan(self) -> None:
        """Issue duplicates for fetches past the p95 deadline (called from
        the assembler loop, so hedging needs no dedicated timer thread)."""
        if self.hedge is None or not self.hedge.enabled:
            return
        deadline = self.hedge.deadline()
        now = time.monotonic()
        stale: List[_Sample] = []
        with self._lock:
            for s, t0 in self._inflight.values():
                if now - t0 > deadline:
                    stale.append(s)
            for s in stale:  # re-arm so one straggler hedges only once
                self._inflight[id(s)] = (s, now + 3600.0)
        for s in stale:
            self.hedge.hedges_issued += 1
            if self._loop is not None:
                # asyncio: the duplicate is one more coroutine on the loop,
                # ungated like the threaded pool's headroom duplicates
                asyncio.run_coroutine_threadsafe(self._ahedge(s), self._loop)
            else:
                self._pool.submit(self._run_hedge, s)

    # -- asyncio fetch -------------------------------------------------------
    async def _acomplete(self, s: _Sample, raw: Any) -> bool:
        """Async mirror of :meth:`_complete`: same first-response-wins pop,
        but the (possibly blocking) decode-queue hand-off runs in an executor
        so other in-flight GETs keep progressing on the event loop."""
        with self._lock:
            if self._inflight.pop(id(s), None) is None:
                return False  # the other copy of a hedged fetch already won
        if self.split:
            s.raw = raw
            await asyncio.get_running_loop().run_in_executor(
                None, self.decode_q.put, s
            )
        else:
            self.done_q.put((s, raw))
        return True

    async def _afetch(self, s: _Sample) -> None:
        t0 = time.monotonic()
        with self._lock:
            self._inflight[id(s)] = (s, t0)
        try:
            fetch = self.dataset.aget_raw if self.split else self.dataset.aget_item
            raw = await aretry_transient(fetch, s.index)
            t1 = time.monotonic()
            self.tracer.record(STAGE_FETCH, t0, t1,
                               index=s.index, batch_id=s.batch_id)
            if self.hedge is not None:
                self.hedge.observe(t1 - t0)
            await self._acomplete(s, raw)
        except BaseException as e:
            self._fail(s, e)
        finally:
            self.gate.release()
            self._kick()

    async def _ahedge(self, s: _Sample) -> None:
        """Ungated asyncio duplicate of a straggling fetch; first wins."""
        t0 = time.monotonic()
        try:
            fetch = self.dataset.aget_raw if self.split else self.dataset.aget_item
            raw = await aretry_transient(fetch, s.index)
            self.tracer.record(STAGE_FETCH, t0, time.monotonic(),
                               index=s.index, batch_id=s.batch_id, hedge=True)
            if await self._acomplete(s, raw) and self.hedge is not None:
                self.hedge.hedges_won += 1
        except BaseException:
            pass  # the original is still in flight; let it decide the outcome

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
        if self._loop is not None:
            def _cancel_and_stop() -> None:
                # cancel in-flight fetch/hedge coroutines before stopping so
                # loop teardown doesn't destroy pending tasks mid-await
                for task in asyncio.all_tasks(self._loop):
                    task.cancel()
                self._loop.call_soon(self._loop.stop)

            self._loop.call_soon_threadsafe(_cancel_and_stop)
            self._thread.join(timeout=5)
            if not self._loop.is_running():
                self._loop.close()


# ---------------------------------------------------------------------------
# CPU stage
# ---------------------------------------------------------------------------


class _CPUStage:
    """decode + augment on a dedicated gated thread pool.

    ``hard_cap`` threads exist; effective parallelism is the gate, so the
    autotuner resizes without thread churn.  The gate is acquired BEFORE
    pulling from the fetch->decode queue — a surplus thread waits empty-
    handed rather than holding a sample hostage behind the gate.

    ``active=False`` parks the stage (threads idle without pulling work):
    the iterator flips it when the ``cpu_executor`` knob swaps the CPU stage
    to the process pool — in-flight samples still finish here, new ones go
    to whichever stage is active, and strict reorder is oblivious to which
    executor produced a sample."""

    def __init__(
        self,
        dataset,
        *,
        width: int,
        hard_cap: int,
        decode_q: _BoundedQ,
        done_q: "queue.Queue",
        stop: threading.Event,
        tracer,
    ) -> None:
        self.dataset = dataset
        self.decode_q = decode_q
        self.done_q = done_q
        self.stop = stop
        self.tracer = tracer
        self.hard_cap = max(width, hard_cap)
        self.gate = AdjustableSemaphore(width)
        self.active = True
        # threads are spawned lazily up to the CURRENT gate width (mirroring
        # ThreadPoolExecutor's lazy growth in the IO stage): a hard_cap of 32
        # must not cost 32 polling threads while the tuned width is 2
        self.threads: List[threading.Thread] = []
        self._spawn_lock = threading.Lock()
        self._ensure_threads(width)

    @property
    def width(self) -> int:
        return self.gate.limit

    def _ensure_threads(self, width: int) -> None:
        with self._spawn_lock:
            while len(self.threads) < min(max(width, 1), self.hard_cap):
                t = threading.Thread(
                    target=self._run, name=f"pipe-cpu-{len(self.threads)}",
                    daemon=True,
                )
                self.threads.append(t)
                t.start()

    def resize(self, width: int) -> int:
        w = max(1, min(int(width), self.hard_cap))
        self.gate.set_limit(w)
        self._ensure_threads(w)
        return w

    def _run(self) -> None:
        while not self.stop.is_set():
            if not self.active:
                time.sleep(0.05)
                continue
            if not self.gate.acquire(timeout=0.1):
                continue
            try:
                try:
                    s: _Sample = self.decode_q.get(timeout=0.1)
                except queue.Empty:
                    continue
                self._process(s)
            finally:
                self.gate.release()

    def _process(self, s: _Sample) -> None:
        try:
            raw, s.raw = s.raw, None
            with self.tracer.span(STAGE_DECODE, index=s.index,
                                  batch_id=s.batch_id):
                decoded = self.dataset.decode_raw(raw, s.index)
            with self.tracer.span(STAGE_AUGMENT, index=s.index,
                                  batch_id=s.batch_id):
                item = self.dataset.augment_item(decoded, s.index)
            self.done_q.put((s, item))
        except BaseException as e:
            self.done_q.put((s, _Failure(e)))

    def join(self, timeout: float = 2.0) -> None:
        for t in self.threads:
            t.join(timeout=timeout)


# ---------------------------------------------------------------------------
# process-backed CPU stage (the GIL escape)
# ---------------------------------------------------------------------------

# attempts per sample across worker crashes: a dead worker fails only its
# in-flight sample, and only after this many fresh workers also died on it
# (then it is almost certainly the sample killing the worker, not bad luck)
PROC_TASK_ATTEMPTS = 3


def _cpu_proc_main(payload: bytes, conn, shm_spec=None) -> None:
    """Spawn entry point for one CPU worker process.

    Runs ONLY ``decode_raw`` + ``augment_item`` on tasks received over the
    pipe; storage IO, assembly and tracing all stay in the parent.  Stage
    endpoints are measured here with ``time.monotonic`` (system-wide
    CLOCK_MONOTONIC) and shipped home so the parent can record real
    per-worker decode/augment spans.  A ``bind`` message replaces the
    dataset wholesale — how the parent pushes per-epoch state (e.g. the
    augmentation epoch) into a pool that outlives iterators.

    ``shm_spec`` = ``(name, slot_bytes, slots)`` attaches the zero-copy
    transport (``PipelineConfig.transport="shm"``): finished samples are
    packed into the parent-owned slab and shipped as ``done_shm`` handles;
    ``free`` returns slots the parent consumed, ``slab_reset`` reclaims
    everything at an epoch takeover, ``slab_cap`` is the autotuner's live
    pressure knob.  Anything that can't pack falls back to the pickle
    ``done`` with the reason attached.  ``die`` is the test-only crash
    injection hook (:meth:`_CPUProcessPool.inject_crash`)."""
    try:
        dataset = pickle.loads(payload)
    except BaseException as e:  # exotic: parent pre-validated pickling
        try:
            conn.send(("crash", f"worker could not unpickle dataset: {e!r}"))
        except OSError:
            pass
        conn.close()
        return
    writer = None
    if shm_spec is not None:
        try:
            writer = shm_mod.SlabWriter(*shm_spec)
        except BaseException as e:
            # segment vanished (parent raced shutdown) — degrade to pipe
            try:
                conn.send(("crash", f"worker could not attach slab: {e!r}"))
            except OSError:
                pass
            writer = None
    die_on_task: Optional[str] = None
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break
        tag = msg[0]
        if tag == "stop":
            break
        if tag == "bind":
            try:
                dataset = pickle.loads(msg[1])
            except BaseException as e:
                try:
                    conn.send(("crash", f"worker could not rebind dataset: {e!r}"))
                except OSError:
                    pass
                break
            continue
        if tag == "free":
            if writer is not None:
                writer.free_slots(msg[1])
            continue
        if tag == "slab_reset":
            if writer is not None:
                writer.reset()
            continue
        if tag == "slab_cap":
            if writer is not None:
                writer.set_cap(msg[1])
            continue
        if tag == "die":
            # crash injection: "now" dies immediately; "mid_slab_write"
            # dies on the NEXT task with a slot claimed and half-written —
            # the handle is never sent, so the parent must reclaim the slot
            # via slab retirement and retry the sample elsewhere
            if msg[1] == "mid_slab_write" and writer is not None:
                die_on_task = msg[1]
                continue
            os._exit(1)
        _, sid, index, raw = msg
        try:
            t0 = time.monotonic()
            decoded = dataset.decode_raw(raw, index)
            t1 = time.monotonic()
            item = dataset.augment_item(decoded, index)
            t2 = time.monotonic()
            if die_on_task == "mid_slab_write":
                slot = writer._take_slot()
                if slot is not None:
                    writer.shm.buf[slot * writer.slot_bytes] = 0xAB
                os._exit(1)
            if writer is not None:
                handle, why = writer.try_pack(item)
                if handle is not None:
                    conn.send(("done_shm", sid, handle, (t0, t1, t2)))
                    continue
            else:
                why = None
            conn.send(("done", sid, item, (t0, t1, t2), why))
        except BaseException as e:
            try:
                pickle.dumps(e)
                exc: BaseException = e
            except Exception:
                exc = RuntimeError(
                    f"cpu worker failed on sample {index}: {e!r}"
                )
            try:
                conn.send(("err", sid, exc))
            except OSError:
                break
    if writer is not None:
        writer.close()
    conn.close()


# tasks in flight per worker: one EXECUTING plus one QUEUED in its pipe.
# The prefilled task hides the parent round trip (result -> pump wakes ->
# dispatch -> child recv), which on a saturated host costs whole scheduler
# quanta — without it every worker idles that long between samples.
PROC_PREFILL_DEPTH = 2


class _ProcWorker:
    """Parent-side handle: process + duplex pipe + in-flight task ids (FIFO:
    the child answers in send order).  ``send_lock`` serializes writes to
    the pipe: during an epoch takeover the outgoing pump can still be
    mid-``send`` (pipe full behind a slow decode) when ``attach`` broadcasts
    the rebind — unsynchronized interleaved writes would corrupt the pickle
    stream."""

    __slots__ = ("proc", "conn", "sids", "send_lock", "slab")

    def __init__(self, proc, conn, slab=None) -> None:
        self.proc = proc
        self.conn = conn
        self.sids: List[int] = []  # at most PROC_PREFILL_DEPTH entries
        self.send_lock = threading.Lock()
        self.slab: Optional[shm_mod.ParentSlab] = slab  # shm transport only

    def send(self, msg: Tuple) -> None:
        with self.send_lock:
            self.conn.send(msg)


def _finalize_pool(slabs: List["shm_mod.ParentSlab"],
                   shutdown: threading.Event) -> None:
    """weakref.finalize target for :class:`_CPUProcessPool` (must not hold
    the pool itself): bar further spawns, then unlink every slab."""
    shutdown.set()
    shm_mod.close_slabs(slabs)


class _CPUProcessPool:
    """Spawn-based decode+augment worker pool, owned by the LOADER.

    Spawning a worker costs hundreds of milliseconds (fresh interpreter +
    numpy import), so unlike the per-epoch thread stages the pool PERSISTS
    across epochs: each epoch's :class:`_ProcCPUStage` attaches to it,
    re-``bind``s the freshly pickled dataset (carrying ``set_epoch`` state),
    and detaches at shutdown without killing workers.  ``owner`` is the
    takeover token — when a new epoch's stage attaches while an abandoned
    iterator's pump thread is still unwinding, the old pump notices it lost
    ownership and exits instead of racing the new one for the pipes.  Task
    ids are pool-global and monotonic, so results from an abandoned epoch's
    tasks are recognized and dropped by the next stage.  Workers are daemon
    processes: an exiting interpreter never hangs on the pool."""

    def __init__(self, payload: bytes, hard_cap: int,
                 shm_spec: Optional[Tuple[int, int]] = None) -> None:
        self.ctx = multiprocessing.get_context("spawn")
        self.payload = payload
        self.hard_cap = max(1, hard_cap)
        self.workers: List[_ProcWorker] = []
        self.owner: Optional[Any] = None
        self.crashes = 0  # workers that died unexpectedly
        self.respawns = 0
        # last child-reported diagnostic ("crash" message): without it, an
        # unpickle/rebind failure in the child surfaces only as a generic
        # "worker died" after the respawn churn burns every retry
        self.last_error: Optional[str] = None
        self._sid = 0
        self._lock = threading.Lock()
        self._closed = False
        # shm transport: (slot_bytes, slots) per worker slab, or None for
        # the pickle pipe.  The parent creates/owns every slab; _slabs is a
        # live list shared with the exit finalizer so segments allocated
        # after respawns are still unlinked if the pool is never closed.
        # The shared _shutdown flag closes a shutdown race: the finalizer
        # runs BEFORE multiprocessing's own atexit terminates the daemon
        # workers, so the (daemon) pump thread may reap those corpses and
        # respawn replacements AFTER the slabs were unlinked — a segment
        # born then has nothing left to clean it up.  ensure() refuses to
        # spawn once the flag is set.
        self.shm_spec = shm_spec
        self.slab_cap: Optional[int] = None  # live usable-slot bound
        self._slabs: List[shm_mod.ParentSlab] = []
        self._shutdown = threading.Event()
        self._finalizer = weakref.finalize(
            self, _finalize_pool, self._slabs, self._shutdown)

    def next_sid(self) -> int:
        with self._lock:
            self._sid += 1
            return self._sid

    def attach(self, stage: Any, payload: bytes) -> None:
        with self._lock:
            self.owner = stage
            rebind = payload != self.payload
            self.payload = payload
        if rebind:
            for w in list(self.workers):  # snapshot: an old pump may mutate
                try:
                    w.send(("bind", payload))
                except OSError:
                    pass  # dead worker; the pump's reap pass replaces it

    def spawn_one(self) -> None:
        parent_conn, child_conn = self.ctx.Pipe()
        slab = None
        worker_spec = None
        if self.shm_spec is not None:
            slab = shm_mod.ParentSlab(*self.shm_spec)
            self._slabs.append(slab)
            worker_spec = slab.spec()
        proc = self.ctx.Process(
            target=_cpu_proc_main,
            args=(self.payload, child_conn, worker_spec),
            name=f"pipe-cpu-proc-{len(self.workers)}",
            daemon=True,
        )
        proc.start()
        child_conn.close()  # the child holds its own copy
        w = _ProcWorker(proc, parent_conn, slab)
        if slab is not None and self.slab_cap is not None:
            # respawned workers must honour the tuned slab-pressure cap too
            try:
                w.send(("slab_cap", self.slab_cap))
            except OSError:  # pragma: no cover - died at birth; reap handles
                pass
        self.workers.append(w)

    def ensure(self, n: int) -> None:
        # under the lock: during an epoch-boundary takeover the outgoing and
        # incoming pump threads briefly coexist, and unsynchronized growth
        # could overshoot hard_cap
        with self._lock:
            if self._closed or self._shutdown.is_set():
                return
            while len(self.workers) < min(max(n, 1), self.hard_cap):
                self.spawn_one()

    def remove(self, w: _ProcWorker) -> None:
        with self._lock:
            if w in self.workers:
                self.workers.remove(w)
        if w.slab is not None:
            # already-delivered views stay valid (parent owns the mapping);
            # the name is dropped now so nothing leaks past the pool
            w.slab.retire()

    def reset_slabs(self) -> None:
        """Epoch takeover: every slot is reclaimed wholesale (a previous
        iterator may have been abandoned with handles it never released)."""
        for w in list(self.workers):
            if w.slab is None:
                continue
            w.slab.reset_accounting()
            try:
                w.send(("slab_reset",))
            except OSError:
                pass  # dead worker; the pump's reap pass replaces it

    def set_slab_cap(self, cap: int) -> None:
        """Autotuner's live slab-pressure knob: bound how many slots each
        worker may use (lower = earlier pickle fallback, less memory hot)."""
        self.slab_cap = cap
        for w in list(self.workers):
            if w.slab is None:
                continue
            try:
                w.send(("slab_cap", cap))
            except OSError:
                pass

    def inject_crash(self, mode: str = "now", worker: int = 0) -> None:
        """TEST HOOK: make worker ``worker`` die — ``"now"`` immediately,
        ``"mid_slab_write"`` on its next task with a slot claimed and
        half-written (exercising crash-safe slot reclamation)."""
        with self._lock:
            if not self.workers:
                raise RuntimeError("no workers to crash")
            w = self.workers[worker % len(self.workers)]
        w.send(("die", mode))

    def close(self) -> None:
        """Terminate every worker (loader replacing the pool / tests).
        Epoch-to-epoch shutdown never calls this — stages just detach."""
        self._closed = True
        for w in self.workers:
            try:
                w.send(("stop",))
            except OSError:
                pass
            w.conn.close()
        for w in self.workers:
            w.proc.join(timeout=0.5)
            if w.proc.is_alive():
                w.proc.terminate()
        self.workers.clear()
        shm_mod.close_slabs(self._slabs)
        self._slabs.clear()


class _ProcCPUStage:
    """decode + augment in the spawn-process pool — same contract as
    :class:`_CPUStage` (pull from ``decode_q``, deliver to ``done_q``,
    gate-bounded parallelism, live resize, ``active`` pause flag) with the
    work itself outside the GIL.

    One parent-side pump thread does everything: it claims samples from the
    fetch->decode queue under the :class:`AdjustableSemaphore` gate (a gate
    permit is held from claim to final resolution, so resizes drain exactly
    like the thread stage), assigns up to :data:`PROC_PREFILL_DEPTH` tasks
    per worker over its pipe (one executing, one queued — the spare hides
    the parent round trip between samples), multiplexes completions with
    ``multiprocessing.connection.wait``, and records the shipped
    decode/augment spans under the worker's pid lane.
    Crash handling: a dead worker's in-flight sample is requeued ahead of
    fresh work and retried on another worker up to ``PROC_TASK_ATTEMPTS``
    total attempts (raw bytes are kept parent-side until success, so a retry
    never refetches), the corpse is reaped and a replacement spawned — one
    crash costs one sample at worst, never the epoch."""

    def __init__(
        self,
        payload: bytes,
        *,
        pool: _CPUProcessPool,
        width: int,
        hard_cap: int,
        decode_q: _BoundedQ,
        done_q: "queue.Queue",
        stop: threading.Event,
        tracer,
    ) -> None:
        self.pool = pool
        self.decode_q = decode_q
        self.done_q = done_q
        self.stop = stop
        self.tracer = tracer
        self.hard_cap = max(width, hard_cap)
        # the gate bounds claimed-but-unresolved samples; it runs at
        # PREFILL_DEPTH x width so every worker can hold a queued spare —
        # `width` stays the stage's parallelism (worker count / knob value)
        self._width = max(1, width)
        self.gate = AdjustableSemaphore(PROC_PREFILL_DEPTH * self._width)
        self.active = True
        self.requeued = 0  # samples retried after a worker crash
        self._inflight: Dict[int, _Sample] = {}
        self._attempts: Dict[int, int] = {}
        self._pending: Deque[int] = deque()  # crash-requeued sids, FIFO
        # transport accounting (stage_stats()["transport"] + bench_shm's
        # bytes-copied claim): pipe samples cost serialize + deserialize
        # (2x payload), shm samples cost the worker's single slab write
        self.shm_samples = 0
        self.pipe_samples = 0
        self.fallbacks: Dict[str, int] = {}
        self.bytes_copied = 0
        pool.attach(self, payload)
        if pool.shm_spec is not None:
            pool.reset_slabs()
        pool.ensure(width)
        self._thread = threading.Thread(
            target=self._run, name="pipe-cpu-pool-pump", daemon=True
        )
        self._thread.start()

    @property
    def width(self) -> int:
        return self._width

    def resize(self, width: int) -> int:
        w = max(1, min(int(width), self.hard_cap))
        self._width = w
        self.gate.set_limit(PROC_PREFILL_DEPTH * w)
        self.pool.ensure(w)
        return w

    # -- pump ---------------------------------------------------------------
    def _owned(self) -> bool:
        return self.pool.owner is self and not self.stop.is_set()

    def _run(self) -> None:
        while self._owned():
            self._reap()
            self.pool.ensure(self._width)
            self._flush_frees()
            self._dispatch()
            workers = list(self.pool.workers)
            busy = [w.conn for w in workers if w.sids]
            if busy:
                for conn in _mp_wait(busy, timeout=0.05):
                    w = next(
                        (x for x in workers if x.conn is conn), None
                    )
                    if w is None:
                        continue
                    try:
                        self._resolve(w, w.conn.recv())
                    except (EOFError, OSError):
                        pass  # worker died mid-send; next reap handles it
            # fully idle case: _dispatch's bounded blocking get is the only
            # wait, so there is nothing further to sleep on here

    def _flush_frees(self) -> None:
        """Return consumed slots to their workers (shm transport): collate
        queued them via ``ShmItem.release``; batching them onto the command
        pipe here keeps the release path lock-only for the consumer."""
        for w in list(self.pool.workers):
            if w.slab is None:
                continue
            pairs = w.slab.drain_freed()
            if not pairs:
                continue
            try:
                w.send(("free", pairs))
            except OSError:
                pass  # dead worker; its slab is retired by the reap pass

    def _dispatch(self) -> None:
        while self._owned():
            # emptiest eligible worker first: fill every idle worker before
            # granting anyone its prefill spare
            candidates = [x for x in list(self.pool.workers)
                          if len(x.sids) < PROC_PREFILL_DEPTH
                          and x.proc.is_alive()]
            if not candidates:
                return
            w = min(candidates, key=lambda x: len(x.sids))
            sid: Optional[int] = None
            if self._pending:
                sid = self._pending.popleft()  # retry holds its permit already
            elif self.active and self.gate.acquire(timeout=0):
                any_busy = any(x.sids for x in self.pool.workers)
                try:
                    # bounded blocking get when the whole stage is idle: the
                    # pump's only sleep, released the instant a fetch lands
                    s = self.decode_q.get(timeout=0.0 if any_busy else 0.05)
                except queue.Empty:
                    self.gate.release()
                    return
                sid = self.pool.next_sid()
                self._inflight[sid] = s
                self._attempts[sid] = 1
            else:
                if not self.active and not self._pending:
                    time.sleep(0.02)  # paused: don't spin on the gate
                return
            s = self._inflight[sid]
            w.sids.append(sid)
            try:
                w.send(("task", sid, s.index, s.raw))
            except OSError:
                w.sids.remove(sid)  # broken pipe = dead worker; reap + retry
                self._retry_or_fail(
                    sid, RuntimeError(
                        f"cpu worker pid={w.proc.pid} lost sample {s.index} "
                        "(pipe closed)"
                    ),
                )

    def _reap(self) -> None:
        dead = [w for w in list(self.pool.workers) if not w.proc.is_alive()]
        for w in dead:
            try:
                while w.conn.poll():  # a result may have beaten the crash
                    self._resolve(w, w.conn.recv())
            except (EOFError, OSError):
                pass
            self.pool.crashes += 1
            why = (f"; last worker diagnostic: {self.pool.last_error}"
                   if self.pool.last_error else "")
            for sid in w.sids:  # executing task + any prefilled spare
                self._retry_or_fail(
                    sid,
                    RuntimeError(
                        f"cpu worker pid={w.proc.pid} died "
                        f"(exitcode={w.proc.exitcode}) while decoding{why}"
                    ),
                )
            w.sids.clear()
            w.conn.close()
            self.pool.remove(w)
            self.pool.respawns += 1

    def _retry_or_fail(self, sid: int, exc: BaseException) -> None:
        s = self._inflight.get(sid)
        if s is None:
            return  # an abandoned epoch's task: nothing to deliver to
        if self._attempts.get(sid, 1) < PROC_TASK_ATTEMPTS:
            self._attempts[sid] = self._attempts.get(sid, 1) + 1
            self.requeued += 1
            self._pending.append(sid)
            return
        del self._inflight[sid]
        self._attempts.pop(sid, None)
        self.done_q.put((s, _Failure(exc)))
        self.gate.release()

    def _resolve(self, w: _ProcWorker, msg: Tuple) -> None:
        tag = msg[0]
        if tag == "crash":
            # the worker is about to exit; reap accounts for it and retries
            # its task (if any).  Keep the child's diagnostic — it is the
            # only evidence of e.g. an unpickle failure inside the worker.
            self.pool.last_error = msg[1]
            return
        sid = msg[1]
        if sid in w.sids:
            w.sids.remove(sid)
        s = self._inflight.pop(sid, None)
        self._attempts.pop(sid, None)
        if s is None:
            return  # stale result from an abandoned epoch's stage
        if tag == "done_shm":
            _, _, handle, (t0, t1, t2) = msg
            item: Any = w.slab.view_item(handle)
            # the worker's slab write is the transport's only copy
            nbytes = handle[2]
            self.shm_samples += 1
            self.bytes_copied += nbytes
            self.tracer.count(BYTES_COPIED, nbytes)
            self._record_proc_spans(w, s, t0, t1, t2)
            s.raw = None
            self.done_q.put((s, item))
        elif tag == "done":
            _, _, item, (t0, t1, t2), why = msg
            # pickle transport: one serialize in the worker, one deserialize
            # here — two full passes over the payload
            nbytes = shm_mod.item_nbytes(item) if isinstance(item, dict) else 0
            self.pipe_samples += 1
            self.bytes_copied += 2 * nbytes
            self.tracer.count(BYTES_COPIED, 2 * nbytes)
            if why is not None:
                self.fallbacks[why] = self.fallbacks.get(why, 0) + 1
            self._record_proc_spans(w, s, t0, t1, t2)
            s.raw = None
            self.done_q.put((s, item))
        else:  # "err": a dataset exception, not a crash — no retry
            self.done_q.put((s, _Failure(msg[2])))
        self.gate.release()

    def _record_proc_spans(self, w: _ProcWorker, s: _Sample,
                           t0: float, t1: float, t2: float) -> None:
        pid = w.proc.pid
        self.tracer.record(STAGE_DECODE, t0, t1, tid=pid,
                           index=s.index, batch_id=s.batch_id, proc=True)
        self.tracer.record(STAGE_AUGMENT, t1, t2, tid=pid,
                           index=s.index, batch_id=s.batch_id, proc=True)

    def join(self, timeout: float = 2.0) -> None:
        self._thread.join(timeout=timeout)


# ---------------------------------------------------------------------------
# assembler / iterator
# ---------------------------------------------------------------------------


class _Group:
    """Window-mode assembly state for up to ``reorder_window`` consecutive
    batches: the group's batch slots are emitted in batch order, each filled
    with the first ``size`` of the group's samples to complete.

    Groups are keyed by dispatch order (a group sequence number), not by
    ``batch_id // window``: each group remembers its own span, so the
    reorder-window knob can change the width live — in-flight groups keep
    the size they were opened with, and only the next group sees the new
    value."""

    __slots__ = ("start_bid", "sizes", "buffer", "indices", "emitted", "closed")

    def __init__(self, start_bid: int) -> None:
        self.start_bid = start_bid  # first dispatched batch_id of the group
        self.sizes: List[int] = []  # batch sizes, in dispatched batch order
        self.buffer: List[Any] = []  # completed items, in completion order
        self.indices: List[int] = []  # dataset indices, completion order
        self.emitted = 0  # batch slots already emitted
        self.closed = False  # a later group was opened: no more batches


class _ShuffleMeter:
    """Windowed shuffle-quality estimator over delivered batch composition.

    Shuffle quality is measured on the *delivered* dataset-index stream
    (what the model actually sees), not the sampler's intent: window-mode
    reassembly fills batches with whichever samples complete first, and
    completion time correlates with content (size, cache state, storage
    locality), silently stratifying batches.  Two normalized [0, 1] numbers:

    * ``within_batch`` — mean normalized Shannon entropy of each batch's
      index histogram over ``buckets`` equal dataset strata.  A uniformly
      shuffled batch draws from every stratum (≈1); a batch stratified by
      completion time concentrates (→0).
    * ``across_batch`` — count-weighted mean, over strata, of the entropy
      of that stratum's distribution across the last ``window_batches``
      batches.  Uniform shuffling spreads each stratum evenly (≈1); epochs
      where a stratum's samples bunch into a few batches score low.

    One :data:`SHUFFLE_ENTROPY` tracer span is recorded per measurement
    window, tagging both values — the audit trail the autotuner's entropy
    floor (``AutotuneConfig.min_shuffle_entropy``) is judged against."""

    def __init__(self, dataset_len: int, tracer, *, buckets: int = 16,
                 window_batches: int = 32) -> None:
        self.n = max(1, int(dataset_len))
        self.buckets = max(2, min(buckets, self.n))
        self.window_batches = max(2, window_batches)
        self.tracer = tracer
        self._hists: Deque[np.ndarray] = deque(maxlen=self.window_batches)
        self._within: Deque[float] = deque(maxlen=self.window_batches)
        self.batches = 0
        self._win_t0: Optional[float] = None

    def note_batch(self, indices) -> None:
        if indices is None or len(indices) == 0:
            return
        now = time.monotonic()
        if self._win_t0 is None:
            self._win_t0 = now
        idx = np.asarray(indices, dtype=np.int64)
        strata = np.minimum(idx * self.buckets // self.n, self.buckets - 1)
        hist = np.bincount(strata, minlength=self.buckets).astype(np.float64)
        p = hist / hist.sum()
        nz = p[p > 0.0]
        hmax = math.log(min(len(idx), self.buckets))
        within = float(-(nz * np.log(nz)).sum() / hmax) if hmax > 0 else 1.0
        self._within.append(within)
        self._hists.append(hist)
        self.batches += 1
        if self.batches % self.window_batches == 0:
            snap = self.snapshot()
            self.tracer.record(
                SHUFFLE_ENTROPY, self._win_t0, now,
                within=snap["within_batch"], across=snap["across_batch"],
                batches=self.batches,
            )
            self._win_t0 = None

    def snapshot(self) -> Dict[str, Any]:
        if not self._within:
            return {"within_batch": None, "across_batch": None, "batches": 0}
        within = float(np.mean(self._within))
        across = None
        if len(self._hists) >= 2:
            m = np.stack(self._hists)  # (batches, strata)
            totals = m.sum(axis=0)  # per-stratum sample counts
            hmax = math.log(m.shape[0])
            acc = 0.0
            for k in range(m.shape[1]):
                if totals[k] <= 0:
                    continue
                q = m[:, k] / totals[k]
                nz = q[q > 0.0]
                acc += float(totals[k]) * float(-(nz * np.log(nz)).sum() / hmax)
            across = acc / float(totals.sum())
        return {
            "within_batch": round(within, 4),
            "across_batch": round(across, 4) if across is not None else None,
            "batches": self.batches,
        }


class _PipelineIter:
    """Iterator over a :class:`~repro.core.loader.ConcurrentDataLoader` in
    pipeline mode — same external contract as ``_LoaderIter`` (ordered or
    windowed delivery, epoch accounting, autotune ``on_batch`` at the safe
    between-batch boundary, shutdown semantics)."""

    def __init__(self, loader) -> None:
        self.loader = loader
        cfg = loader.cfg
        self.cfg = cfg
        self.tracer = loader.tracer
        at = cfg.autotune
        dataset = loader.dataset
        pipe = cfg.pipeline
        self.split = bool(dataset.supports_split())
        self.strict = pipe.reorder == "strict"
        self.window = 1 if self.strict else max(1, pipe.reorder_window)

        # stage sizing: 0 derives io_workers from the legacy loader's total
        # fetch-thread count so pipeline-vs-legacy runs at equal concurrency
        io_workers = pipe.io_workers or max(1, cfg.num_workers * cfg.num_fetch_workers)
        cpu_workers = pipe.cpu_workers or 4
        queue_depth = max(1, pipe.stage_queue_depth)
        self.max_outstanding = max(1, cfg.num_workers * cfg.prefetch_factor)
        # knob ceilings widen over the static config (enabling autotune must
        # never cap the loader below its autotune=off operating point)
        self._max_io_bound = max(at.max_fetch_workers, io_workers)
        self._max_cpu_bound = max(at.max_cpu_workers, cpu_workers)
        self._max_queue_bound = max(at.max_stage_queue, queue_depth)
        self._max_outstanding_bound = max(at.max_outstanding, self.max_outstanding)
        if at.enabled:
            # resume from values the controller already learned (prev epoch)
            tuned = loader._tuned
            if not self.strict:
                self.window = min(
                    max(tuned.get("reorder_window", self.window),
                        at.min_reorder_window),
                    max(at.max_reorder_window, self.window),
                )
            io_workers = min(
                max(tuned.get("io_workers", io_workers), at.min_fetch_workers),
                self._max_io_bound,
            )
            cpu_workers = min(
                max(tuned.get("cpu_workers", cpu_workers), at.min_cpu_workers),
                self._max_cpu_bound,
            )
            queue_depth = min(
                max(tuned.get("stage_queue", queue_depth), at.min_stage_queue),
                self._max_queue_bound,
            )
            self.max_outstanding = min(
                max(tuned.get("outstanding", self.max_outstanding),
                    at.min_outstanding),
                self._max_outstanding_bound,
            )

        # budget co-tuning (AutotuneConfig.thread_budget): io and cpu widths
        # are one coupled knob under a fixed total, so normalize the static
        # shape onto the budget here — the split value is the IO width and
        # the CPU stage always gets the remainder
        self._budget = (
            at.thread_budget
            if at.enabled and at.thread_budget > 0 and self.split
            else 0
        )
        if at.enabled and at.thread_budget > 0 and not self.split:
            # monolithic fallback: no CPU stage to trade against, but the
            # budget is still a promise about total width — cap the IO knob
            # at it rather than silently reverting to the unbounded ceiling
            self._max_io_bound = min(self._max_io_bound, at.thread_budget)
            io_workers = min(io_workers, at.thread_budget)
        self._split_lo = self._split_hi = 0
        if self._budget:
            b = self._budget
            self._split_lo = max(at.min_fetch_workers, b - self._max_cpu_bound, 1)
            self._split_hi = max(self._split_lo, b - max(at.min_cpu_workers, 1))
            seed = io_workers
            if pipe.io_workers == 0 and "io_cpu_split" not in loader._tuned:
                # cores-aware split seed: the CPU stage is compute-bound, so
                # start it near the cores this process may actually use
                # (cgroup quota aware) and give IO the budget's remainder —
                # the co-tuner then begins near the optimum instead of at a
                # constant derived from fetch-thread counts
                from repro.core.utilization import available_cpu_count

                seed = b - available_cpu_count()
            io_workers = min(
                max(loader._tuned.get("io_cpu_split", seed),
                    self._split_lo),
                self._split_hi,
            )
            cpu_workers = b - io_workers

        # CPU executor kind: static config, overridden by the tuned value
        # when the budget co-tuner flipped it in a previous epoch
        self.cpu_kind = pipe.cpu_executor if self.split else "thread"
        if at.enabled and self.split and "cpu_executor" in loader._tuned:
            self.cpu_kind = (
                "process" if loader._tuned["cpu_executor"] else "thread"
            )
        # the process stage ships a pickled dataset copy to each spawn
        # worker (decode/augment state only — see MapDataset's picklability
        # contract).  Pickle once, up front: a clear construction-time error
        # beats an opaque one from inside a worker.
        self._proc_payload: Optional[bytes] = None
        if self.split and (
            self.cpu_kind == "process"
            or (self._budget and at.tune_cpu_executor)
        ):
            try:
                self._proc_payload = pickle.dumps(dataset)
            except Exception as e:
                if self.cpu_kind == "process":
                    raise ValueError(
                        "cpu_executor='process' requires a picklable dataset "
                        "(the process CPU stage ships a pickled copy to each "
                        "spawn worker; drop store/tracer members on pickle — "
                        "see MapDataset's picklability contract): "
                        f"pickling failed with {e!r}"
                    ) from e
                self._proc_payload = None  # exec-kind knob just unavailable

        # process-stage result transport: the zero-copy slab ring only means
        # something when a process stage can exist (split + picklable);
        # everything else keeps the pickle pipe (and the thread stage has no
        # transport at all — items never leave the process)
        self.transport = "pipe"
        self._shm_spec: Optional[Tuple[int, int]] = None
        if pipe.transport == "shm" and self._proc_payload is not None:
            self.transport = "shm"
            self._shm_spec = (pipe.slab_slot_bytes, pipe.slab_slots)
        # slab-pressure knob state (usable-slot cap <= allocated slots)
        self._slab_cap = self._shm_spec[1] if self._shm_spec else 0
        if at.enabled and self._shm_spec and "slab_slots" in loader._tuned:
            self._slab_cap = min(
                max(loader._tuned["slab_slots"], at.min_slab_slots),
                self._shm_spec[1],
            )

        self._stop = threading.Event()
        self.decode_q = _BoundedQ(queue_depth, self._stop)
        self.done_q: "queue.Queue" = queue.Queue()
        # sharded delivery: lane threads collate + device-transfer each mesh
        # slice of the batch and push the composed global array back into
        # done_q as a (_Composed, batch) token (repro.core.delivery)
        self._assembler = None
        # pinned host staging (repro.core.staging): only meaningful for the
        # default collate (a custom collate_fn owns its own batch layout)
        from repro.data.dataset import collate as _default_collate

        staging_n = (
            pipe.staging_buffers
            if loader.collate_fn is _default_collate else 0
        )
        if loader.delivery_plan is not None:
            from repro.core.delivery import ShardedAssembler  # lazy: jax

            self._assembler = ShardedAssembler(
                loader.delivery_plan,
                loader.collate_fn,
                done_q=self.done_q,
                stop=self._stop,
                tracer=self.tracer,
                staging_buffers=staging_n,
            )
        self._staging = None
        if staging_n > 0 and self._assembler is None:
            from repro.core.staging import HostBatchPool

            self._staging = HostBatchPool(depth=staging_n, tracer=self.tracer)
        self.io = _IOStage(
            dataset,
            mode="asyncio" if cfg.impl == "asyncio" else "threaded",
            width=io_workers,
            hard_cap=self._max_io_bound if at.enabled else io_workers,
            split=self.split,
            decode_q=self.decode_q,
            done_q=self.done_q,
            stop=self._stop,
            tracer=self.tracer,
            hedge=loader.hedge,
        )
        cpu_hard = self._max_cpu_bound if at.enabled else cpu_workers
        if not self.split:
            # monolithic fallback: the fetch stage already produces finished
            # items, so the CPU stage processes nothing — don't spin up an
            # idle thread pool (much less a process pool) for it
            cpu_workers = cpu_hard = 1
        self._cpu_hard = cpu_hard
        self._cpu_width = cpu_workers
        # both CPU stage kinds share decode_q/done_q and are created lazily;
        # the inactive one (if ever created) is paused, so the cpu_executor
        # knob can swap kinds mid-epoch without disturbing in-flight samples
        self._thread_cpu: Optional[_CPUStage] = None
        self._proc_cpu: Optional[_ProcCPUStage] = None
        self.cpu = self._make_cpu_stage(self.cpu_kind)

        self._sampler_iter = iter(loader.sampler)
        self._exhausted = False
        self._shutdown = False
        self._lock = threading.Lock()
        self._dispatched_samples = 0
        self._completed_samples = 0
        self._dispatched_batches = 0
        self._emitted_batches = 0
        self._bid_base = 0  # first dispatched batch_id (resume offsets it)
        self._max_bid = -1  # highest dispatched batch_id (group closure)
        # samples per batch, learned from the first dispatched task: sharded
        # batches hold batch_size/num_hosts indices, so sizing the window
        # from cfg.batch_size would admit num_hosts x more batches than the
        # legacy loader's prefetch window
        self._per_batch: Optional[int] = None
        # strict-mode assembly: per-batch positional slots + ready buffer
        self._slots: Dict[int, List[Any]] = {}
        self._remaining: Dict[int, int] = {}
        self._ready: Dict[int, Any] = {}
        self._next_bid: Optional[int] = None
        # window-mode assembly: per-group first-N-ready composition, keyed
        # by dispatch-order group sequence number (live-resizable window)
        self._groups: Dict[int, _Group] = {}
        self._cur_group = 0  # next group to deliver
        self._next_gid = 0  # next group to open
        self._gid_of_bid: Dict[int, int] = {}
        self._group_consumed = 0  # absolute bid past the last emitted group
        # shuffle-quality estimator over the delivered index stream (the
        # evidence behind stage_stats()["shuffle"] and the autotuner's
        # reorder-window entropy floor)
        self._shuffle = _ShuffleMeter(loader.sampler.dataset_len, self.tracer)
        # strict/sharded batch composition equals the sampler's dispatch —
        # remember it so delivery can be scored without re-deriving indices
        self._batch_indices: Dict[int, Tuple[int, ...]] = {}

        if loader.autotuner is not None:
            from repro.core.autotune import (
                build_budget_knobs,
                build_pipeline_knobs,
                make_weak_knob_callbacks,
            )

            # knob callbacks reach this iterator through a weakref (see
            # make_weak_knob_callbacks): the autotuner outlives every
            # epoch's iterator, and a strong closure would pin an abandoned
            # iterator (and its stage threads) until the next bind().
            _wget, _wset = make_weak_knob_callbacks(self)
            # slab-pressure knob only when the shm transport is live (the
            # slab allocation caps how far the controller may raise it)
            extra_kw: Dict[str, Any] = {}
            if self._shm_spec is not None:
                extra_kw = dict(
                    get_slab=_wget(lambda it: it._slab_cap),
                    set_slab=_wset(lambda it, n: it._set_slab_slots(n)),
                    max_slab=self._shm_spec[1],
                )
            # reorder-window knob only where the window exists: window-mode
            # host delivery (sharded delivery requires strict reorder)
            if not self.strict and self._assembler is None:
                extra_kw.update(
                    get_reorder=_wget(lambda it: it.window),
                    set_reorder=_wset(lambda it, n: it._set_reorder_window(n)),
                )
            if self._budget:
                # budget co-tuning: ONE coupled io/cpu split knob (+ the
                # executor kind when the dataset is process-capable) instead
                # of two independent width knobs
                proc_ok = self._proc_payload is not None
                knobs = build_budget_knobs(
                    at,
                    budget=self._budget,
                    lo_split=self._split_lo,
                    hi_split=self._split_hi,
                    get_split=_wget(lambda it: it.io.gate.limit),
                    set_split=_wset(lambda it, n: it._set_split(n)),
                    get_outstanding=_wget(lambda it: it.max_outstanding),
                    set_outstanding=_wset(lambda it, n: it._set_outstanding(n)),
                    get_queue=_wget(lambda it: it.decode_q.depth),
                    set_queue=_wset(lambda it, n: it._set_stage_queue(n)),
                    get_cpu_executor=(
                        _wget(lambda it: int(it.cpu_kind == "process"))
                        if proc_ok else None
                    ),
                    set_cpu_executor=(
                        _wset(lambda it, n: it._set_cpu_executor(n))
                        if proc_ok else None
                    ),
                    hedge=loader.hedge,
                    max_outstanding=self._max_outstanding_bound,
                    max_queue=self._max_queue_bound,
                    **extra_kw,
                )
            else:
                knobs = build_pipeline_knobs(
                    at,
                    get_io=_wget(lambda it: it.io.gate.limit),
                    set_io=_wset(lambda it, n: it._set_io_workers(n)),
                    get_cpu=_wget(lambda it: it.cpu.width),
                    set_cpu=_wset(lambda it, n: it._set_cpu_workers(n)),
                    get_outstanding=_wget(lambda it: it.max_outstanding),
                    set_outstanding=_wset(lambda it, n: it._set_outstanding(n)),
                    get_queue=_wget(lambda it: it.decode_q.depth),
                    set_queue=_wset(lambda it, n: it._set_stage_queue(n)),
                    hedge=loader.hedge,
                    max_io=self._max_io_bound,
                    max_cpu=self._max_cpu_bound,
                    max_outstanding=self._max_outstanding_bound,
                    max_queue=self._max_queue_bound,
                    **extra_kw,
                )
                if not self.split:
                    # nothing flows through the CPU stage or its queue —
                    # inert knobs would waste the controller's probe windows
                    knobs = [k for k in knobs
                             if k.name not in ("cpu_workers", "stage_queue")]
            loader.autotuner.bind(knobs)
            for knob in loader._cache_knobs:
                loader.autotuner.attach_knob(knob)

        self._pump()

    # -- CPU stage factory / executor swap -----------------------------------
    def _make_cpu_stage(self, kind: str):
        """Create (or reactivate) the CPU stage of the requested kind.  Both
        kinds share decode_q/done_q/stop; the process kind attaches to the
        loader-persistent :class:`_CPUProcessPool` (spawn cost is paid once,
        not per epoch) and rebinding ships this epoch's dataset state."""
        if kind == "process":
            if self._proc_cpu is None:
                pool = self.loader._cpu_pool
                if (pool is None or pool.hard_cap < self._cpu_hard
                        or pool._closed or pool.shm_spec != self._shm_spec):
                    if pool is not None:
                        pool.close()
                    pool = _CPUProcessPool(self._proc_payload, self._cpu_hard,
                                           shm_spec=self._shm_spec)
                    self.loader._cpu_pool = pool
                if self._shm_spec and self._slab_cap < self._shm_spec[1]:
                    pool.set_slab_cap(self._slab_cap)
                self._proc_cpu = _ProcCPUStage(
                    self._proc_payload,
                    pool=pool,
                    width=self._cpu_width,
                    hard_cap=self._cpu_hard,
                    decode_q=self.decode_q,
                    done_q=self.done_q,
                    stop=self._stop,
                    tracer=self.tracer,
                )
            else:
                self._proc_cpu.active = True
                self._proc_cpu.resize(self._cpu_width)
            return self._proc_cpu
        if self._thread_cpu is None:
            self._thread_cpu = _CPUStage(
                self.loader.dataset,
                width=self._cpu_width,
                hard_cap=self._cpu_hard,
                decode_q=self.decode_q,
                done_q=self.done_q,
                stop=self._stop,
                tracer=self.tracer,
            )
        else:
            self._thread_cpu.active = True
            self._thread_cpu.resize(self._cpu_width)
        return self._thread_cpu

    # -- autotuner control surfaces (applied between batches) ----------------
    def _set_io_workers(self, n: int) -> int:
        n = max(self.cfg.autotune.min_fetch_workers, int(n))
        applied = self.io.resize(n)
        self.loader._tuned["io_workers"] = applied
        return applied

    def _resize_cpu(self, n: int) -> int:
        applied = self.cpu.resize(n)
        self._cpu_width = applied
        return applied

    def _set_cpu_workers(self, n: int) -> int:
        n = max(self.cfg.autotune.min_cpu_workers, int(n))
        applied = self._resize_cpu(n)
        self.loader._tuned["cpu_workers"] = applied
        return applied

    def _set_split(self, n: int) -> int:
        """Apply one value of the coupled io/cpu split (budget mode): IO gets
        ``n``, the CPU stage gets ``budget - n``.  The shrinking side is
        resized first so the LIMITS never sum above the budget, even
        transiently (surplus in-flight work drains through its gate)."""
        n = max(self._split_lo, min(int(n), self._split_hi))
        cpu = self._budget - n
        if n >= self.io.gate.limit:
            self._resize_cpu(cpu)
            self.io.resize(n)
        else:
            self.io.resize(n)
            self._resize_cpu(cpu)
        self.loader._tuned["io_cpu_split"] = n
        return n

    def _set_cpu_executor(self, v: int) -> int:
        """Swap the CPU stage kind live (binary budget-mode knob).  The old
        stage is paused, not torn down: its in-flight samples finish into
        the shared done_q (strict reorder is executor-oblivious), and a
        revert two windows later reactivates it for free."""
        want = "process" if int(v) >= 1 else "thread"
        cur = int(self.cpu_kind == "process")
        if want == self.cpu_kind:
            return cur
        if want == "process" and self._proc_payload is None:
            return cur  # not process-capable: echo so the controller skips
        old = self.cpu
        self.cpu = self._make_cpu_stage(want)
        old.active = False
        self.cpu_kind = want
        applied = int(want == "process")
        self.loader._tuned["cpu_executor"] = applied
        return applied

    def _set_outstanding(self, n: int) -> int:
        at = self.cfg.autotune
        n = max(at.min_outstanding, min(int(n), self._max_outstanding_bound))
        self.max_outstanding = n
        self.loader._tuned["outstanding"] = n
        return n

    def _set_stage_queue(self, n: int) -> int:
        n = max(self.cfg.autotune.min_stage_queue, int(n))
        applied = self.decode_q.resize(n, self._max_queue_bound)
        self.loader._tuned["stage_queue"] = applied
        return applied

    def _set_slab_slots(self, n: int) -> int:
        """Slab-pressure knob (shm transport): cap the usable slots per
        worker slab.  Allocation is fixed at construction (slab_slots), so
        the cap only gates which slots the worker may hand out — lowering
        it never touches in-flight slots, it just forces earlier pickle
        fallback; raising it re-admits parked slots on their next free."""
        at = self.cfg.autotune
        hi = self._shm_spec[1] if self._shm_spec else 1
        n = max(at.min_slab_slots, min(int(n), hi))
        self._slab_cap = n
        stage = self._proc_cpu
        if stage is not None:
            stage.pool.set_slab_cap(n)
        self.loader._tuned["slab_slots"] = n
        return n

    def _set_reorder_window(self, n: int) -> int:
        """Reorder-window knob (window mode only): takes effect for the NEXT
        opened group — groups are keyed by dispatch order and remember their
        own span, so in-flight groups keep the size they were opened with
        and the assembly math never sees a mixed window."""
        if self.strict:
            return 1
        at = self.cfg.autotune
        n = max(at.min_reorder_window,
                min(int(n), max(at.max_reorder_window, 1)))
        self.window = n
        self.loader._tuned["reorder_window"] = n
        return n

    # -- dispatch ------------------------------------------------------------
    def _pump(self) -> None:
        """Flatten sampler batches into sample tasks while the in-flight
        sample window has room (the batch-level ``outstanding`` knob times
        the actual per-batch sample count, matching the legacy prefetch
        window even when host sharding shrinks each batch's index list)."""
        if self._exhausted:
            return
        while (
            self._per_batch is None  # first batch sizes the window
            or self._dispatched_samples - self._completed_samples
            < self.max_outstanding * self._per_batch
        ):
            try:
                task: BatchIndices = next(self._sampler_iter)
            except StopIteration:
                self._exhausted = True
                return
            if self._per_batch is None:
                self._per_batch = max(len(task.indices), 1)
            if self._next_bid is None:
                self._next_bid = task.batch_id
                self._bid_base = task.batch_id
                self._group_consumed = task.batch_id
            self._max_bid = max(self._max_bid, task.batch_id)
            n = len(task.indices)
            if self._assembler is not None:
                self._assembler.begin_batch(task.batch_id, n)
                self._batch_indices[task.batch_id] = tuple(task.indices)
            elif self.strict:
                self._slots[task.batch_id] = [None] * n
                self._remaining[task.batch_id] = n
                self._batch_indices[task.batch_id] = tuple(task.indices)
            else:
                gid = self._next_gid - 1
                g = self._groups.get(gid)
                if g is None or g.closed or len(g.sizes) >= self.window:
                    if g is not None:
                        g.closed = True
                    gid = self._next_gid
                    self._next_gid += 1
                    g = _Group(task.batch_id)
                    self._groups[gid] = g
                g.sizes.append(n)
                self._gid_of_bid[task.batch_id] = gid
            self._dispatched_batches += 1
            self._dispatched_samples += n
            for pos, index in enumerate(task.indices):
                self.io.submit(_Sample(task.batch_id, pos, index))

    # -- assembly ------------------------------------------------------------
    def _absorb(self, s: _Sample, item: Any) -> None:
        self._completed_samples += 1
        if self._assembler is not None:
            # lane routing: the assembler hands the sample to its lane's
            # collate/h2d thread; the composed batch comes back through
            # done_q as a _Composed token, landing in _ready below
            self._assembler.add(s.batch_id, s.pos, item)
        elif self.strict:
            slots = self._slots[s.batch_id]
            slots[s.pos] = item
            self._remaining[s.batch_id] -= 1
            if self._remaining[s.batch_id] == 0:
                del self._remaining[s.batch_id]
                self._ready[s.batch_id] = self._slots.pop(s.batch_id)
        else:
            g = self._groups[self._gid_of_bid[s.batch_id]]
            g.buffer.append(item)
            g.indices.append(s.index)

    def _pop_ready(self) -> Optional[List[Any]]:
        """Return the next deliverable batch's items, or None."""
        if self.strict:
            if self._next_bid is not None and self._next_bid in self._ready:
                items = self._ready.pop(self._next_bid)
                self._shuffle.note_batch(
                    self._batch_indices.pop(self._next_bid, ()))
                self._next_bid += 1
                return items
            return None
        g = self._groups.get(self._cur_group)
        if g is None:
            return None
        if g.emitted < len(g.sizes):
            need = g.sizes[g.emitted]
            if len(g.buffer) >= need:
                items, g.buffer = g.buffer[:need], g.buffer[need:]
                idxs, g.indices = g.indices[:need], g.indices[need:]
                g.emitted += 1
                self._shuffle.note_batch(idxs)
                if g.emitted == len(g.sizes) and (g.closed or self._exhausted):
                    # last slot of a finished group: the consumer cursor may
                    # advance past it (resume replays partial groups only)
                    self._group_consumed = g.start_bid + len(g.sizes)
                return items
            return None
        # every dispatched slot of this group emitted; the group is complete
        # once a later group was opened (dispatch is in batch-id order) or
        # the sampler is exhausted — then advance
        if (g.closed or self._exhausted) and not g.buffer:
            self._group_consumed = g.start_bid + len(g.sizes)
            for bid in range(g.start_bid, g.start_bid + len(g.sizes)):
                self._gid_of_bid.pop(bid, None)
            del self._groups[self._cur_group]
            self._cur_group += 1
            return self._pop_ready()
        return None

    def _emit(self, items: List[Any]) -> Any:
        if self._assembler is not None:
            # sharded delivery: the lane threads already collated and
            # device-transferred every shard — `items` IS the composed,
            # device-resident global batch
            batch = items
        else:
            # absolute batch id, same coordinate space as the per-sample
            # stage spans (which carry the sampler's batch_id) — joinable
            # after resume
            with self.tracer.span(
                STAGE_COLLATE, batch_id=self._bid_base + self._emitted_batches
            ):
                if self._staging is not None:
                    batch = self._staging.collate(items)
                else:
                    batch = self.loader.collate_fn(items)
            # collate is one full pass over the batch either way (np.stack
            # allocates+copies; staging copies into a reused buffer)
            if isinstance(batch, dict):
                self.tracer.count(BYTES_COPIED, shm_mod.item_nbytes(batch))
            # collate copied every view out — hand shm slots back for reuse
            shm_mod.release_items(items)
        self._emitted_batches += 1
        # consumer cursor in absolute batch ids (resume starts past 0), same
        # contract as the legacy iterator's _next_bid bookkeeping
        consumed = self._bid_base + self._emitted_batches
        if not self.strict:
            # a windowed batch holds first-N-ready samples from its whole
            # group, so a mid-group cursor would resume with some samples
            # dropped and others duplicated; hold the cursor at the last
            # fully emitted group's end (maintained in _pop_ready) — a
            # restart replays the partial group, which is the legacy
            # "prefetched-but-unconsumed batches are replayed" contract,
            # and no sample is ever lost
            consumed = max(self._group_consumed, self._bid_base)
        self.loader._consumed = consumed
        return batch

    # -- iteration -----------------------------------------------------------
    def __iter__(self) -> "_PipelineIter":
        return self

    def __next__(self) -> Any:
        from repro.core.loader import deliver_traced  # here to avoid a cycle

        return deliver_traced(self)

    def _next_impl(self) -> Any:
        if self._shutdown:
            raise StopIteration
        from repro.core.loader import LoaderTimeout  # here to avoid a cycle

        deadline = time.monotonic() + self.cfg.timeout_s
        while True:
            items = self._pop_ready()
            if items is not None:
                self._pump()
                return self._emit(items)
            if (
                self._exhausted
                and self._completed_samples >= self._dispatched_samples
                and self._emitted_batches >= self._dispatched_batches
            ):
                self._finish_epoch()
                raise StopIteration
            self._pump()
            self.io.hedge_scan()
            try:
                s, payload = self.done_q.get(timeout=0.1)
            except queue.Empty:
                if time.monotonic() > deadline:
                    self.shutdown()
                    raise LoaderTimeout(
                        f"no sample within {self.cfg.timeout_s}s (dispatched="
                        f"{self._dispatched_samples}, "
                        f"completed={self._completed_samples})"
                    )
                continue
            if isinstance(payload, _Failure):
                self.shutdown()
                raise payload.exc
            if isinstance(s, _Composed):
                # a lane assembler finished a global batch out of band; park
                # it for the strict in-order pop above
                self._ready[s.batch_id] = payload
                continue
            self._absorb(s, payload)

    def _finish_epoch(self) -> None:
        self.shutdown()
        self.loader._note_epoch_end()

    # -- observability -------------------------------------------------------
    def stage_stats(self) -> Dict[str, Any]:
        """Live per-stage snapshot: executor widths, queue occupancy, flow
        counters — the queue numbers are what identify the bottleneck stage
        (and what bench_pipeline asserts overlap with)."""
        out: Dict[str, Any] = {
            "io_workers": self.io.gate.limit,
            "cpu_workers": self.cpu.width,
            "cpu_executor": self.cpu_kind,
            "outstanding_batches": self.max_outstanding,
            "decode_queue": self.decode_q.occupancy(),
            "done_queue": self.done_q.qsize(),
            "in_flight_samples": self._dispatched_samples - self._completed_samples,
            "emitted_batches": self._emitted_batches,
            "split": self.split,
            "reorder": "strict" if self.strict else f"window={self.window}",
            # delivered-stream shuffle quality (see _ShuffleMeter): the
            # within_batch value feeds the autotuner's reorder-window
            # entropy floor via the loader's entropy_fn
            "shuffle": self._shuffle.snapshot(),
        }
        if self._budget:
            out["thread_budget"] = self._budget
        if self._staging is not None:
            out["staging"] = self._staging.stats()
        if self._proc_cpu is not None:
            pool = self._proc_cpu.pool
            out["cpu_pool"] = {
                "workers": len(pool.workers),
                "crashes": pool.crashes,
                "respawns": pool.respawns,
                "requeued": self._proc_cpu.requeued,
            }
            if pool.last_error:
                out["cpu_pool"]["last_error"] = pool.last_error
            stage = self._proc_cpu
            samples = stage.shm_samples + stage.pipe_samples
            tr: Dict[str, Any] = {
                "kind": self.transport,
                "shm_samples": stage.shm_samples,
                "pipe_samples": stage.pipe_samples,
                "fallbacks": dict(stage.fallbacks),
                "fallback_rate": (
                    round(sum(stage.fallbacks.values()) / samples, 4)
                    if samples else 0.0
                ),
                "bytes_copied": stage.bytes_copied,
            }
            if pool.shm_spec is not None:
                slot_bytes, slots = pool.shm_spec
                live = [w.slab for w in pool.workers if w.slab is not None]
                in_use = sum(s.in_use for s in live)
                peak = max((s.peak for s in live), default=0)
                total = slots * max(len(live), 1)
                tr.update(
                    slot_bytes=slot_bytes,
                    slab_slots=slots,
                    slab_cap=self._slab_cap,
                    slots_in_use=in_use,
                    slots_peak_per_worker=peak,
                    occupancy=round(in_use / total, 4) if total else 0.0,
                )
            out["transport"] = tr
        hedge = self.io.hedge
        if hedge is not None:
            out["hedges_issued"] = hedge.hedges_issued
            out["hedges_won"] = hedge.hedges_won
        if self._assembler is not None:
            # per-lane composed counts / collate / h2d means — the lane-skew
            # signal autotune and bench_sharded read
            out["delivery"] = self._assembler.stats()
        return out

    # -- shutdown ------------------------------------------------------------
    def shutdown(self) -> None:
        with self._lock:
            if self._shutdown:
                return
            self._shutdown = True
        # final snapshot for post-epoch observability: the loader holds this
        # iterator only weakly (so threads are never pinned), but callers
        # still want stage_stats() after the epoch ends
        try:
            self.loader._last_stage_stats = self.stage_stats()
        except Exception:  # pragma: no cover - stats must never block exit
            pass
        self._stop.set()
        if self._assembler is not None:
            self._assembler.close()
        self.io.close()
        # join every CPU stage ever created this epoch (an executor-kind
        # flip leaves the paused one alive); the process POOL persists on
        # the loader — only the pump thread belongs to this iterator
        for stage in (self._thread_cpu, self._proc_cpu):
            if stage is not None:
                stage.join()

    def __del__(self) -> None:  # pragma: no cover - best effort
        try:
            self.shutdown()
        except Exception:
            pass
