"""Jitted serving programs: prefill / decode per architecture family."""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import encdec, transformer


def make_serve_fns(cfg: ModelConfig) -> Dict[str, Callable]:
    """Returns dict(init_cache, prefill, decode) for the family."""
    if cfg.family == "encdec":
        return {
            "init_cache": lambda batch, max_len: encdec.init_dec_cache(cfg, batch, max_len),
            "prefill": lambda params, batch, cache: encdec.prefill(params, batch, cfg, cache),
            "decode": lambda params, cache, tok, pos: encdec.decode_step(
                params, cache, tok, pos, cfg
            ),
        }
    return {
        "init_cache": lambda batch, max_len: transformer.init_cache(cfg, batch, max_len),
        "prefill": lambda params, batch, cache: transformer.prefill(params, batch, cfg, cache),
        "decode": lambda params, cache, tok, pos: transformer.decode_step(
            params, cache, tok, pos, cfg
        ),
    }


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits: jnp.ndarray, key, temperature: float = 1.0) -> jnp.ndarray:
    return jax.random.categorical(key, logits / max(temperature, 1e-5)).astype(jnp.int32)
