"""Batched serving engine with continuous batching ("-lite").

Fixed pool of B slots over a shared KV cache.  Each engine tick decodes one
token for every active slot (a single jitted ``decode_step`` with per-slot
positions).  When a slot finishes (EOS / max tokens), the next queued request
is prefilled into that slot (batch-1 prefill, scattered into the pooled
cache) without stalling the other slots — the serving analogue of the
paper's "keep the workers busy" principle.

Prompts stream from an ObjectStore through the ConcurrentDataLoader-style
fetch path, so high-latency storage benefits identically at inference time.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field, replace
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeSpec
from repro.serve.steps import greedy_sample, make_serve_fns


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    """Engine sizing comes from a :class:`repro.config.ServeSpec`; the
    historical flat ``num_slots=``/``max_len=`` kwargs still work through a
    warn-once deprecation shim (``replace()`` on a spec round-trips
    silently — see README "Online serving read path")."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        spec: Optional[ServeSpec] = None,
        num_slots: Optional[int] = None,
        max_len: Optional[int] = None,
    ) -> None:
        legacy = {}
        for name, val in (("num_slots", num_slots), ("max_len", max_len)):
            if val is not None:
                warnings.warn(
                    f"ServeEngine({name}=...) is deprecated and will be"
                    f" removed; pass spec=ServeSpec({name}=...) instead",
                    DeprecationWarning, stacklevel=2,
                )
                legacy[name] = val
        spec = spec if spec is not None else ServeSpec()
        if legacy:
            spec = replace(spec, **legacy)
        self.cfg = cfg
        self.params = params
        self.spec = spec
        self.num_slots = spec.num_slots
        self.max_len = spec.max_len
        fns = make_serve_fns(cfg)
        self._init_cache = fns["init_cache"]
        # slot-0 prefill program (batch 1) + pooled decode program
        self._prefill1 = jax.jit(fns["prefill"])
        self._decode = jax.jit(fns["decode"])
        self.cache = self._init_cache(self.num_slots, self.max_len)
        self.positions = np.zeros((self.num_slots,), np.int32)
        self.last_token = np.zeros((self.num_slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * self.num_slots
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self._uid = 0
        self.ticks = 0
        self.tokens_generated = 0

    # -- request API -----------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        self._uid += 1
        req = Request(
            self._uid, np.asarray(prompt, np.int32), max_new_tokens, eos_id,
            t_submit=time.monotonic(),
        )
        self.queue.append(req)
        return self._uid

    # -- internals ---------------------------------------------------------------
    def _scatter_cache(self, slot: int, cache1: Any) -> None:
        """Write a batch-1 cache into row ``slot`` of the pooled cache."""

        # generic: the batch axis position differs per family; use tree map
        # with dynamic_update_slice on the axis whose size == num_slots.
        def upd(pool, one):
            # find batch axis: first axis where pool.shape[i] == num_slots and
            # one.shape[i] == 1
            for ax in range(pool.ndim):
                if pool.shape[ax] == self.num_slots and one.shape[ax] == 1:
                    idx = [0] * pool.ndim
                    idx[ax] = slot
                    return jax.lax.dynamic_update_slice(pool, one.astype(pool.dtype), tuple(idx))
            raise ValueError(f"no batch axis found: {pool.shape} vs {one.shape}")

        self.cache = jax.tree.map(upd, self.cache, cache1)

    def _admit(self) -> None:
        for slot in range(self.num_slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            P = len(req.prompt)
            if P >= self.max_len:
                raise ValueError(f"prompt length {P} >= max_len {self.max_len}")
            batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
            if self.cfg.family == "encdec":
                t_enc = self.cfg.encoder_seq_len or 1500
                fd = self.cfg.frontend_dim or self.cfg.d_model
                batch["frames"] = jnp.zeros((1, t_enc, fd), jnp.float32)
            cache1 = self._init_cache(1, self.max_len)
            logits, cache1 = self._prefill1(self.params, batch, cache1)
            tok = int(np.asarray(greedy_sample(logits))[0])
            self._scatter_cache(slot, cache1)
            req.t_first_token = time.monotonic()
            req.output.append(tok)
            self.active[slot] = req
            self.positions[slot] = P
            self.last_token[slot] = tok

    def _retire(self, slot: int) -> None:
        req = self.active[slot]
        assert req is not None
        req.t_done = time.monotonic()
        self.completed.append(req)
        self.active[slot] = None

    def step(self) -> int:
        """One engine tick: admit -> batched decode -> sample -> retire.
        Returns number of tokens generated this tick."""
        self._admit()
        live = [s for s in range(self.num_slots) if self.active[s] is not None]
        if not live:
            return 0
        toks = jnp.asarray(self.last_token, jnp.int32)[:, None]
        pos = jnp.asarray(self.positions, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        nxt = np.asarray(greedy_sample(logits))
        produced = 0
        for s in live:
            req = self.active[s]
            tok = int(nxt[s])
            req.output.append(tok)
            produced += 1
            self.positions[s] += 1
            self.last_token[s] = tok
            done = len(req.output) >= req.max_new_tokens or (
                req.eos_id is not None and tok == req.eos_id
            )
            if done or self.positions[s] + 1 >= self.max_len:
                self._retire(s)
        self.ticks += 1
        self.tokens_generated += produced
        return produced

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        while (self.queue or any(a is not None for a in self.active)) and self.ticks < max_ticks:
            self.step()
        return self.completed
