"""Multi-tenant online-serving read path over the tiered cache + fetcher stack.

The training loaders optimize epoch wall-time; :class:`ReadPath` opens the
second workload the ROADMAP names — millions of users issuing skewed, bursty
reads against the same ``TieredCacheStore``/origin stack — where the metric
is *tail latency*.  Three mechanisms, each independently configurable through
:class:`repro.config.ServeSpec`:

* **Single-flight coalescing** — concurrent misses on one key share a single
  backend fetch (one leader, N waiters); the completed result is held for
  ``coalesce_window_s`` so a flash crowd arriving just after completion still
  coalesces instead of stampeding the origin.  A crashed leader wakes every
  waiter and exactly one re-registers as the retry leader.
* **Per-tenant fairness** — token-bucket byte budgets on the *shared* tiers
  (:class:`repro.config.TenantPolicy`): disk-tier and origin service debit
  the tenant's bucket (memory hits are free), and a tenant in debt blocks
  before its next backend read until the bucket refills — one hot tenant
  cannot starve the rest of disk/NIC service.
* **SLO-driven hedged reads** — ``hedge="slo"`` derives the duplicate-fetch
  delay from the live backend-latency distribution against the p99 target
  (fire at ``max(hedge_min_s, slo_p99_s - p50)``, the latest moment a typical
  duplicate can still finish inside the SLO) instead of a fixed delay, with a
  sustained duplicate-rate budget.

With ``ServeSpec.autotune.enabled`` (``objective="latency"``) the path runs
an :class:`repro.core.autotune.AutotuneController` fed per-request latencies:
the hedge delay, coalesce window, and (tiered-cache stacks) the cache knobs
hill-climb against the p99 target.  Every request records a ``serve_get``
tracing span; ``benchmarks/bench_serve.py`` replays Zipf/diurnal/flash-crowd
traces over this class for the p50/p99/p999 claims.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.config import ServeSpec, TenantPolicy
from repro.core.autotune import (
    AutotuneController,
    build_cache_knobs,
    build_serve_knobs,
)
from repro.core.tracing import NULL_TRACER, SERVE_GET, Tracer

HEDGE_MODES = ("off", "fixed", "slo")

# a waiter woken by a failed flight re-enters the begin() race this many
# times (each round elects one new retry leader) before surfacing the error
_MAX_WAITER_RETRIES = 2


@dataclass
class ReadResult:
    """One served request.  ``source``: which mechanism produced the bytes —
    ``memory``/``disk`` (cache tier hit), ``coalesced`` (shared another
    request's backend fetch), or ``fetch`` (this request led its own)."""

    key: str
    data: bytes
    tenant: str
    source: str
    latency_s: float = 0.0
    hedged: bool = False
    throttled_s: float = 0.0  # time blocked on the tenant's byte budget


def _pctl(sorted_xs: List[float], q: float) -> float:
    if not sorted_xs:
        return 0.0
    return sorted_xs[min(int(len(sorted_xs) * q), len(sorted_xs) - 1)]


class _TokenBucket:
    """Post-paid byte budget: backend service *debits* the bucket (possibly
    into debt — an object's size is unknown until fetched), and a tenant in
    debt blocks before its NEXT backend read until refill clears the debt.
    The sustained rate is therefore enforced to within one object size of
    ``rate_bytes_per_s``, with ``burst`` bytes of slack for idle tenants."""

    def __init__(self, rate_bytes_per_s: float, burst_bytes: float,
                 clock: Callable[[], float], sleep: Callable[[float], None]) -> None:
        self.rate = float(rate_bytes_per_s)
        self.burst = float(burst_bytes) if burst_bytes > 0 else self.rate
        self._level = self.burst
        self._clock = clock
        self._sleep = sleep
        self._t = clock()
        self._lock = threading.Lock()
        self.charged_bytes = 0
        self.waited_s = 0.0

    @property
    def metered(self) -> bool:
        return self.rate > 0

    def _refill_locked(self) -> None:
        now = self._clock()
        self._level = min(self.burst, self._level + (now - self._t) * self.rate)
        self._t = now

    def level(self) -> float:
        with self._lock:
            self._refill_locked()
            return self._level

    def charge(self, nbytes: int) -> None:
        if not self.metered:
            return
        with self._lock:
            self._refill_locked()
            self._level -= nbytes
            self.charged_bytes += nbytes

    def wait_for_credit(self, timeout: Optional[float] = None) -> float:
        """Block until the bucket is out of debt (level > 0); returns the
        seconds waited.  Refill is purely time-based, so the wait sleeps the
        computed deficit directly (chunked to stay timeout-responsive)."""
        if not self.metered:
            return 0.0
        t0 = self._clock()
        deadline = None if timeout is None else t0 + timeout
        while True:
            with self._lock:
                self._refill_locked()
                if self._level > 0:
                    break
                need = -self._level / self.rate + 1e-4
            now = self._clock()
            if deadline is not None:
                if now >= deadline:
                    break
                need = min(need, deadline - now)
            self._sleep(min(need, 0.25))
        waited = self._clock() - t0
        with self._lock:
            self.waited_s += waited
        return waited


class _Flight:
    __slots__ = ("done", "data", "error", "t_start", "t_done")

    def __init__(self, now: float) -> None:
        self.done = threading.Event()
        self.data: Optional[bytes] = None
        self.error: Optional[BaseException] = None
        self.t_start = now
        self.t_done = 0.0


class _SingleFlight:
    """Per-key flight table: at most one in-flight backend fetch per key;
    concurrent misses join the leader's flight and share its bytes.  A
    completed flight is HELD for the coalesce window (so a burst arriving
    just after completion still coalesces); a failed flight is dropped
    immediately and wakes every waiter — the first to re-enter ``begin``
    becomes the retry leader, the rest re-wait on the new flight."""

    def __init__(self, window_fn: Callable[[], float],
                 clock: Callable[[], float]) -> None:
        self._window_fn = window_fn  # live: the coalesce window is a knob
        self._clock = clock
        self._lock = threading.Lock()
        self._flights: Dict[str, _Flight] = {}
        self._begins = 0

    def begin(self, key: str) -> Tuple[_Flight, bool]:
        """Returns ``(flight, is_leader)``."""
        now = self._clock()
        with self._lock:
            self._begins += 1
            if self._begins % 256 == 0:
                self._prune_locked(now)
            fl = self._flights.get(key)
            if fl is not None:
                if not fl.done.is_set():
                    return fl, False  # join the in-flight fetch
                if fl.error is None and now - fl.t_done <= self._window_fn():
                    return fl, False  # completed result still held
            nf = _Flight(now)
            self._flights[key] = nf
            return nf, True

    def finish(self, key: str, flight: _Flight, data: Optional[bytes] = None,
               error: Optional[BaseException] = None) -> None:
        with self._lock:
            flight.data = data
            flight.error = error
            flight.t_done = self._clock()
            if error is not None and self._flights.get(key) is flight:
                del self._flights[key]
        flight.done.set()

    def held(self) -> int:
        with self._lock:
            return len(self._flights)

    def _prune_locked(self, now: float) -> None:
        window = self._window_fn()
        stale = [
            k for k, fl in self._flights.items()
            if fl.done.is_set() and now - fl.t_done > window
        ]
        for k in stale:
            del self._flights[k]


class _Hedger:
    """Duplicate-fetch policy.  ``fixed`` fires after a constant delay;
    ``slo`` derives the delay from the live backend-latency distribution
    against the tail target — fire at ``max(hedge_min_s, slo_p99_s - p50)``,
    the latest moment a typical duplicate can still finish inside the SLO.
    Most fetches complete before the derived delay, so only true stragglers
    pay for a duplicate, and ``hedge_budget_fraction`` bounds the sustained
    duplicate rate regardless of the delay."""

    CALIBRATION_SAMPLES = 16

    def __init__(self, spec: ServeSpec) -> None:
        self.mode = spec.hedge
        self._fixed = spec.hedge_delay_s
        self._floor = spec.hedge_min_s
        self._slo = spec.slo_p99_s
        self._budget = spec.hedge_budget_fraction
        self._durs: Deque[float] = deque(maxlen=256)
        self._lock = threading.Lock()
        self.requests = 0
        self.issued = 0
        self.won = 0
        self.delay_override_s = 0.0  # autotune knob; 0 = policy-derived

    def note_request(self) -> None:
        with self._lock:
            self.requests += 1

    def observe(self, dur_s: float) -> None:
        with self._lock:
            self._durs.append(dur_s)

    def delay(self) -> Optional[float]:
        """Seconds to wait before duplicating, or None (don't hedge)."""
        if self.mode == "off":
            return None
        if self.delay_override_s > 0:
            return self.delay_override_s
        if self.mode == "fixed":
            return self._fixed
        with self._lock:
            durs = sorted(self._durs)
        if len(durs) < self.CALIBRATION_SAMPLES:
            return None  # calibrating: no hedges until p50 is known
        p50 = durs[len(durs) // 2]
        return max(self._floor, self._slo - p50)

    def allow(self) -> bool:
        """One combined budget check + issue count (atomic under the lock)."""
        if self._budget <= 0:
            return False
        with self._lock:
            if self.issued >= self._budget * max(self.requests, 1):
                return False
            self.issued += 1
            return True

    def record_win(self) -> None:
        with self._lock:
            self.won += 1

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "mode": self.mode,
                "requests": self.requests,
                "issued": self.issued,
                "won": self.won,
                "delay_s": self.delay_override_s or None,
            }


class _Tenant:
    __slots__ = ("name", "policy", "bucket", "sem", "lock", "requests",
                 "by_source", "backend_bytes", "lat")

    def __init__(self, name: str, policy: TenantPolicy,
                 clock: Callable[[], float],
                 sleep: Callable[[float], None]) -> None:
        self.name = name
        self.policy = policy
        self.bucket = _TokenBucket(
            policy.rate_bytes_per_s, float(policy.burst_bytes), clock, sleep
        )
        self.sem = (
            threading.BoundedSemaphore(policy.max_inflight)
            if policy.max_inflight > 0 else None
        )
        self.lock = threading.Lock()
        self.requests = 0
        self.by_source = {"memory": 0, "disk": 0, "coalesced": 0, "fetch": 0}
        self.backend_bytes = 0
        self.lat: Deque[float] = deque(maxlen=8192)


class ReadPath:
    """Multi-tenant GET front end over any ``ObjectStore``-shaped store.

    When the store is a :class:`repro.data.cache.TieredCacheStore` its
    cache-only ``lookup`` serves memory/disk hits without entering
    single-flight, so coalescing and metering apply exactly to the requests
    that cost backend service.  ``clock``/``sleep`` are injectable for
    deterministic tests."""

    def __init__(self, store: Any, spec: Optional[ServeSpec] = None, *,
                 tracer: Tracer = NULL_TRACER,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep) -> None:
        spec = spec if spec is not None else ServeSpec()
        if spec.hedge not in HEDGE_MODES:
            raise ValueError(
                f"unknown hedge mode {spec.hedge!r}; known: {HEDGE_MODES}"
            )
        self.store = store
        self.spec = spec
        self.tracer = tracer
        self._clock = clock
        self._sleep = sleep
        self._window_s = float(spec.coalesce_window_s)
        self._sf = _SingleFlight(lambda: self._window_s, clock)
        self._hedger = _Hedger(spec)
        pool_width = spec.max_inflight if spec.max_inflight > 0 else 64
        self._pool = ThreadPoolExecutor(
            max_workers=max(4, min(pool_width, 256)),
            thread_name_prefix="readpath",
        )
        self._gate = (
            threading.BoundedSemaphore(spec.max_inflight)
            if spec.max_inflight > 0 else None
        )
        self._policies = {p.tenant: p for p in spec.tenants}
        self._default_policy = self._policies.get("*", TenantPolicy())
        self._tenants: Dict[str, _Tenant] = {}
        self._tlock = threading.Lock()
        # single-flight audit: primary (non-hedge) backend fetch start times
        # per key — benchmarks assert <= 1 start per key per coalesce window
        self._audit_lock = threading.Lock()
        self._fetch_log: Dict[str, List[float]] = {}
        self._hedge_log: Dict[str, int] = {}
        self._closed = False
        # latency-objective closed-loop control
        self.autotuner: Optional[AutotuneController] = None
        self._at_lock = threading.Lock()
        at = spec.autotune
        if at.enabled:
            if at.objective != "latency":
                raise ValueError(
                    "ReadPath autotuning scores request latencies: set"
                    ' ServeSpec.autotune.objective="latency"'
                )
            knobs = build_serve_knobs(at, self)
            if at.tune_cache and hasattr(store, "cache_stats"):
                knobs += build_cache_knobs(at, store)
            self.autotuner = AutotuneController(at, knobs, tracer=tracer)

    # -- autotune knob surfaces (milliseconds: the controller is integer) ----
    @property
    def hedge_mode(self) -> str:
        return self._hedger.mode

    def hedge_delay_ms(self) -> int:
        d = (self._hedger.delay_override_s or self._hedger.delay()
             or self.spec.hedge_delay_s)
        return max(1, int(round(d * 1000)))

    def set_hedge_delay_ms(self, v: int) -> int:
        v = max(1, int(v))
        self._hedger.delay_override_s = v / 1000.0
        return v

    def coalesce_ms(self) -> int:
        return int(round(self._window_s * 1000))

    def set_coalesce_ms(self, v: int) -> int:
        v = max(1, int(v))
        self._window_s = v / 1000.0
        return v

    # -- request surface -----------------------------------------------------
    def get(self, key: str, tenant: str = "default",
            timeout: Optional[float] = None) -> ReadResult:
        if self._closed:
            raise RuntimeError("ReadPath is closed")
        t0 = self._clock()
        ten = self._tenant(tenant)
        self._hedger.note_request()
        res = self._serve(key, ten, timeout)
        end = self._clock()
        res.latency_s = end - t0
        self.tracer.record(
            SERVE_GET, t0, end, tenant=ten.name, source=res.source,
            hedged=res.hedged, nbytes=len(res.data),
        )
        with ten.lock:
            ten.requests += 1
            ten.by_source[res.source] += 1
            ten.lat.append(res.latency_s)
        if self.autotuner is not None:
            # serialize: the controller's state machine is single-threaded
            with self._at_lock:
                self.autotuner.on_request(res.latency_s, now=end)
        return res

    def _tenant(self, name: str) -> _Tenant:
        with self._tlock:
            ten = self._tenants.get(name)
            if ten is None:
                pol = self._policies.get(name, self._default_policy)
                ten = _Tenant(name, pol, self._clock, self._sleep)
                self._tenants[name] = ten
            return ten

    def _serve(self, key: str, ten: _Tenant,
               timeout: Optional[float]) -> ReadResult:
        # 1. cache tiers.  Memory hits are free (no shared-resource
        # contention); disk service debits the tenant's budget but never
        # blocks — accumulated debt gates the tenant's NEXT backend read.
        peek = getattr(self.store, "lookup", None)
        if peek is not None:
            hit = peek(key)
            if hit is not None:
                data, tier = hit
                if tier == "disk":
                    ten.bucket.charge(len(data))
                return ReadResult(key, data, ten.name, tier)
        # 2. miss: the backend fetch path
        if self._window_s <= 0:
            # coalescing disabled (the uncoalesced baseline): every miss
            # fetches independently
            waited = ten.bucket.wait_for_credit(timeout)
            data, hedged = self._fetch(key, ten)
            return ReadResult(key, data, ten.name, "fetch",
                              hedged=hedged, throttled_s=waited)
        retries = 0
        while True:
            fl, leader = self._sf.begin(key)
            if leader:
                # fairness gates the LEADER only — waiters piling onto this
                # flight consume no extra backend service, and a throttled
                # tenant's followers queue behind its leader's credit wait
                waited = ten.bucket.wait_for_credit(timeout)
                try:
                    data, hedged = self._fetch(key, ten)
                except BaseException as e:
                    self._sf.finish(key, fl, error=e)
                    raise
                self._sf.finish(key, fl, data=data)
                return ReadResult(key, data, ten.name, "fetch",
                                  hedged=hedged, throttled_s=waited)
            if not fl.done.wait(timeout):
                raise TimeoutError(
                    f"coalesced read of {key!r} timed out after {timeout}s"
                )
            if fl.error is None:
                assert fl.data is not None
                return ReadResult(key, fl.data, ten.name, "coalesced")
            # the leader's fetch crashed: every waiter lands here and
            # re-enters begin() — the race elects exactly one retry leader,
            # the rest re-wait on the new flight
            retries += 1
            if retries > _MAX_WAITER_RETRIES:
                raise fl.error

    def _fetch(self, key: str, ten: _Tenant) -> Tuple[bytes, bool]:
        """One backend fetch (possibly hedged), audited and metered."""
        t0 = self._clock()
        with self._audit_lock:
            log = self._fetch_log.setdefault(key, [])
            log.append(t0)
            if len(log) > 4096:
                del log[0]
        if ten.sem is not None:
            ten.sem.acquire()
        if self._gate is not None:
            self._gate.acquire()
        try:
            delay = self._hedger.delay()
            if delay is None:
                data, hedged = self.store.get(key), False
            else:
                data, hedged = self._hedged_fetch(key, delay)
        finally:
            if self._gate is not None:
                self._gate.release()
            if ten.sem is not None:
                ten.sem.release()
        self._hedger.observe(self._clock() - t0)
        ten.bucket.charge(len(data))
        with ten.lock:
            ten.backend_bytes += len(data)
        return data, hedged

    def _hedged_fetch(self, key: str, delay: float) -> Tuple[bytes, bool]:
        primary = self._pool.submit(self.store.get, key)
        done, _ = wait({primary}, timeout=delay)
        if done or not self._hedger.allow():
            return primary.result(), False
        with self._audit_lock:
            self._hedge_log[key] = self._hedge_log.get(key, 0) + 1
        backup = self._pool.submit(self.store.get, key)
        pending = {primary, backup}
        while pending:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            for f in done:
                if f.exception() is None:
                    if f is backup:
                        self._hedger.record_win()
                    return f.result(), True
            # the finisher errored: fall through to whichever copy remains
        return primary.result(), True  # both failed — surface the primary's

    # -- observability -------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        tenants: Dict[str, Any] = {}
        with self._tlock:
            items = list(self._tenants.items())
        for name, ten in items:
            with ten.lock:
                lat = sorted(ten.lat)
                tenants[name] = {
                    "requests": ten.requests,
                    "by_source": dict(ten.by_source),
                    "backend_bytes": ten.backend_bytes,
                    "throttle_wait_s": round(ten.bucket.waited_s, 6),
                    "p50_s": _pctl(lat, 0.50),
                    "p99_s": _pctl(lat, 0.99),
                }
        return {
            "tenants": tenants,
            "hedge": self._hedger.stats(),
            "coalesce_window_s": self._window_s,
            "flights_held": self._sf.held(),
        }

    def audit_fetches(self) -> Dict[str, List[float]]:
        """Per-key primary (non-hedge) backend fetch start times."""
        with self._audit_lock:
            return {k: list(v) for k, v in self._fetch_log.items()}

    def audit_hedges(self) -> Dict[str, int]:
        with self._audit_lock:
            return dict(self._hedge_log)

    def audit_max_fetches_per_window(
            self, window_s: Optional[float] = None) -> int:
        """Worst case over keys: the max number of primary backend fetch
        starts inside any sliding window of ``window_s`` (default: the
        coalesce window).  A healthy coalescing path reports <= 1 — a
        completed flight is held for the window, so consecutive fetch starts
        for one key are strictly more than a window apart."""
        w = self._window_s if window_s is None else window_s
        worst = 0
        for times in self.audit_fetches().values():
            times.sort()
            j = 0
            for i in range(len(times)):
                while times[i] - times[j] > w:
                    j += 1
                worst = max(worst, i - j + 1)
        return worst

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        self._closed = True
        self._pool.shutdown(wait=False)

    def __enter__(self) -> "ReadPath":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
