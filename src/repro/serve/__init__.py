"""Serving substrate: prefill/decode programs + continuous-batching engine."""
