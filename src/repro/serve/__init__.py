"""Serving substrate: prefill/decode programs + continuous-batching engine,
plus the multi-tenant online read path (:mod:`repro.serve.readpath`).

The read path is jax-free and imported eagerly; the engine pulls in jax and
is resolved lazily so ``from repro.serve import ReadPath`` works on data-only
hosts (mirrors how ``repro.core`` keeps its factory jax-optional)."""
from repro.serve.readpath import ReadPath, ReadResult

__all__ = ["ReadPath", "ReadResult", "Request", "ServeEngine"]


def __getattr__(name: str):
    if name in ("ServeEngine", "Request"):
        from repro.serve import engine

        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
