"""Pure-jnp oracle for fused RMSNorm."""
import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """x: (..., d); scale: (d,).  fp32 accumulation, output in x.dtype."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)
