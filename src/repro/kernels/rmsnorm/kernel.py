"""Fused RMSNorm Pallas TPU kernel.

Tiling: rows are processed in VMEM blocks of (BLOCK_ROWS, d) — one pass,
fused mean-square + rsqrt + scale (vs. 3 HBM round-trips unfused).  d stays
whole in the lane dimension (d is a multiple of 128 for every assigned
arch), BLOCK_ROWS rides the sublane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, d)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_2d(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    eps: float = 1e-6,
    block_rows: int = BLOCK_ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (n, d) with n % block_rows == 0 handled by padding in ops.py."""
    n, d = x.shape
    block_rows = min(block_rows, n)
    assert n % block_rows == 0
    grid = (n // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale)
