"""Jit-able wrapper: arbitrary leading dims, row padding, interpret toggle."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import BLOCK_ROWS, rmsnorm_2d


@functools.partial(jax.jit, static_argnames=("eps", "interpret", "block_rows"))
def rmsnorm(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    eps: float = 1e-6,
    interpret: bool = False,
    block_rows: int = BLOCK_ROWS,
) -> jnp.ndarray:
    shape = x.shape
    d = shape[-1]
    n = 1
    for s in shape[:-1]:
        n *= s
    flat = x.reshape(n, d)
    block = min(block_rows, n)
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad, d), flat.dtype)])
    out = rmsnorm_2d(flat, scale, eps=eps, block_rows=block, interpret=interpret)
    if pad:
        out = out[:n]
    return out.reshape(shape)
