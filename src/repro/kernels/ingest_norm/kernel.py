"""Fused device-side ingest Pallas TPU kernel.

The DALI-style fix the paper cites (Zolnouri et al.): move the CPU-bound
tail of the augmentation pipeline (dequantize + normalize + layout) onto the
accelerator.  The host ships raw uint8 HWC (4x fewer PCIe/ICI bytes than
f32), the kernel fuses u8->f32 dequant, per-channel affine normalize and the
HWC->CHW layout flip in one VMEM pass per image block.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ingest_kernel(img_ref, mean_ref, std_ref, o_ref):
    x = img_ref[0].astype(jnp.float32) / 255.0  # (H, W, C)
    mean = mean_ref[...].astype(jnp.float32)
    std = std_ref[...].astype(jnp.float32)
    y = (x - mean[None, None, :]) / std[None, None, :]
    o_ref[0] = y.transpose(2, 0, 1).astype(o_ref.dtype)  # (C, H, W)


def ingest_norm_batched(
    img_u8: jnp.ndarray,  # (B, H, W, C) uint8
    mean: jnp.ndarray,
    std: jnp.ndarray,
    *,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, W, C = img_u8.shape
    return pl.pallas_call(
        _ingest_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, W, C), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((C,), lambda b: (0,)),
            pl.BlockSpec((C,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, C, H, W), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C, H, W), out_dtype),
        interpret=interpret,
    )(img_u8, mean, std)
