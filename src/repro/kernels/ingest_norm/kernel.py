"""Fused device-side ingest Pallas TPU kernel.

The DALI-style fix the paper cites (Zolnouri et al.): move the CPU-bound
tail of the augmentation pipeline (dequantize + normalize + layout) onto the
accelerator.  The host ships raw uint8 HWC (4x fewer PCIe/ICI bytes than
f32), the kernel fuses u8->f32 dequant, per-channel affine normalize and the
HWC->CHW layout flip in one VMEM pass per image block.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ingest_kernel(img_ref, scale_ref, bias_ref, o_ref):
    # dequant + normalize folded into one fma per element:
    #   (x/255 - mean)/std  ==  x * (1/(255*std)) + (-mean/std)
    # scale/bias are precomputed outside the kernel, so the whole epilogue is
    # a cast, a multiply-add, and the layout flip — one VMEM pass per image.
    x = img_ref[0].astype(jnp.float32)  # (H, W, C)
    scale = scale_ref[...].astype(jnp.float32)
    bias = bias_ref[...].astype(jnp.float32)
    y = x * scale[None, None, :] + bias[None, None, :]
    o_ref[0] = y.transpose(2, 0, 1).astype(o_ref.dtype)  # (C, H, W)


def ingest_norm_batched(
    img_u8: jnp.ndarray,  # (B, H, W, C) uint8
    mean: jnp.ndarray,
    std: jnp.ndarray,
    *,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, W, C = img_u8.shape
    std_f = std.astype(jnp.float32)
    scale = 1.0 / (255.0 * std_f)
    bias = -mean.astype(jnp.float32) / std_f
    return pl.pallas_call(
        _ingest_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, W, C), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((C,), lambda b: (0,)),
            pl.BlockSpec((C,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, C, H, W), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C, H, W), out_dtype),
        interpret=interpret,
    )(img_u8, scale, bias)
