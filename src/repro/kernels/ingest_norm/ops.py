"""Jit-able wrappers for fused ingest.

``ingest_norm`` is the raw array op (u8 NHWC -> normalized f32 NCHW).
``make_ingest_fn`` packages it as the batch-level epilogue the training loop
hands to :class:`repro.core.prefetch.DevicePrefetchRing`: a jitted
dict -> dict callable that replaces a uint8 HWC ``image`` with the
normalized CHW tensor the model expects, leaving every other key (and any
batch that already arrived as f32 from the host epilogue) untouched.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ingest_norm.kernel import ingest_norm_batched
from repro.kernels.ingest_norm.ref import ingest_norm_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def ingest_norm(img_u8, mean, std, interpret: bool = False):
    return ingest_norm_batched(img_u8, mean, std, interpret=interpret)


def make_ingest_fn(
    mean: Optional[Any] = None,
    std: Optional[Any] = None,
    *,
    key: str = "image",
    out_dtype: Any = jnp.float32,
    impl: str = "auto",
    interpret: bool = False,
) -> Any:
    """Build the on-device ingest epilogue for ``DevicePrefetchRing``.

    ``mean``/``std`` default to the ImageNet constants (matching the host
    :func:`repro.data.augment.to_tensor_normalize`).  ``impl`` picks the
    kernel: ``"pallas"`` (the fused fma kernel), ``"ref"`` (pure jnp, what
    XLA fuses on CPU/GPU), or ``"auto"`` (pallas on TPU, ref elsewhere —
    interpret-mode pallas would serialize the grid on CPU).

    The returned callable is safe to apply to any batch dict: it only
    rewrites ``key`` when it finds a uint8 NHWC array, so host-epilogue
    batches and non-image pipelines pass through unchanged (the dtype check
    happens at trace time — no device-side branching).
    """
    if impl not in ("auto", "pallas", "ref"):
        raise ValueError(f"impl must be auto|pallas|ref, got {impl!r}")
    if mean is None or std is None:
        from repro.data.augment import IMAGENET_MEAN, IMAGENET_STD

        mean = IMAGENET_MEAN if mean is None else mean
        std = IMAGENET_STD if std is None else std
    mean = jnp.asarray(np.asarray(mean, dtype=np.float32))
    std = jnp.asarray(np.asarray(std, dtype=np.float32))
    use_pallas = impl == "pallas" or (
        impl == "auto" and jax.default_backend() == "tpu"
    )

    @jax.jit
    def ingest(batch: Dict[str, Any]) -> Dict[str, Any]:
        img = batch.get(key) if hasattr(batch, "get") else None
        if img is None or img.dtype != jnp.uint8 or img.ndim != 4:
            return dict(batch) if isinstance(batch, dict) else batch
        if use_pallas:
            out = ingest_norm_batched(
                img, mean, std, out_dtype=out_dtype, interpret=interpret
            )
        else:
            out = ingest_norm_ref(img, mean, std, out_dtype=out_dtype)
        new = dict(batch)
        new[key] = out
        return new

    return ingest
