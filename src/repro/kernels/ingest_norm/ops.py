"""Jit-able wrapper for fused ingest."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ingest_norm.kernel import ingest_norm_batched


@functools.partial(jax.jit, static_argnames=("interpret",))
def ingest_norm(img_u8, mean, std, interpret: bool = False):
    return ingest_norm_batched(img_u8, mean, std, interpret=interpret)
