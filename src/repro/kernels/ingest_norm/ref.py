"""Pure-jnp oracle: uint8 HWC -> normalized float CHW (the paper's
`transform` tail: to-tensor + normalize), fused device-side."""
import jax.numpy as jnp


def ingest_norm_ref(
    img_u8: jnp.ndarray,  # (B, H, W, C) uint8
    mean: jnp.ndarray,  # (C,) in [0,1] units
    std: jnp.ndarray,  # (C,)
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    x = img_u8.astype(jnp.float32) / 255.0
    x = (x - mean.astype(jnp.float32)) / std.astype(jnp.float32)
    return x.transpose(0, 3, 1, 2).astype(out_dtype)  # (B, C, H, W)
