"""Pure-jnp oracle: dense softmax attention (O(S^2) memory)."""
import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, H, T, D)
    v: jnp.ndarray,  # (B, H, T, D)
    causal: bool = True,
) -> jnp.ndarray:
    D = q.shape[-1]
    s = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) / math.sqrt(D)
    if causal:
        S, T = q.shape[2], k.shape[2]
        mask = jnp.arange(T)[None, :] <= (jnp.arange(S)[:, None] + (T - S))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bhtd->bhsd", p, v)
