"""Jit-able wrapper: (B,H,S,D) layout, GQA head expansion, seq padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import BLOCK_K, BLOCK_Q, flash_attention_bh


@functools.partial(
    jax.jit, static_argnames=("causal", "interpret", "block_q", "block_k")
)
def flash_attention(
    q: jnp.ndarray,  # (B, Hq, S, D)
    k: jnp.ndarray,  # (B, Hkv, T, D)
    v: jnp.ndarray,  # (B, Hkv, T, D)
    causal: bool = True,
    interpret: bool = False,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
) -> jnp.ndarray:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    if Hkv != Hq:  # GQA: expand kv heads to query heads
        G = Hq // Hkv
        k = jnp.repeat(k, G, axis=1)
        v = jnp.repeat(v, G, axis=1)
    bq = min(block_q, S)
    bk = min(block_k, k.shape[2])
    pad_q = (-S) % bq
    pad_k = (-k.shape[2]) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # pad keys at the FRONT would break causal offset; pad at the end and
        # rely on causal masking (padded keys are in the future of all real q)
        assert causal or pad_k == 0, "non-causal padding unsupported"
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    qf = q.reshape(B * Hq, S + pad_q, D)
    kf = k.reshape(B * Hq, k.shape[2], D)
    vf = v.reshape(B * Hq, v.shape[2], D)
    out = flash_attention_bh(
        qf, kf, vf, causal=causal, block_q=bq, block_k=bk, interpret=interpret
    )
    out = out.reshape(B, Hq, S + pad_q, D)
    return out[:, :, :S]
