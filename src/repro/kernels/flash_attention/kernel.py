"""Blocked causal attention (flash-style) Pallas TPU kernel.

TPU adaptation of the GPU flash algorithm: instead of a warp-level softmax
with shared-memory tiles, we use the canonical TPU formulation — a 3-D grid
``(batch*heads, q_blocks, kv_blocks)`` where the innermost kv dimension is a
*sequential* revisit of the same output block.  Online-softmax statistics
(m, l) and the fp32 accumulator live in VMEM scratch between kv iterations;
``@pl.when(kv==0)`` initializes, ``@pl.when(kv==last)`` finalizes and writes
the output tile.  Block shapes (BLOCK_Q x D, BLOCK_K x D) are MXU-aligned
(multiples of 128 in the lane dim via D; 128 rows feed the 128x128 MXU).

Memory: O(S) per core (one q tile + one kv tile + accumulator) — this is
what makes prefill_32k lowerable where dense S^2 scores would need 4 GiB.
Causality skips fully-masked kv blocks via ``pl.when`` (upper-triangle tiles
cost a predicate, not a matmul).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, sm_scale: float, block_q: int, block_k: int,
                  kv_blocks: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset  # global q rows
    k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)

    # skip tiles strictly above the causal diagonal
    run = True
    if causal:
        run = (ki * block_k) <= (qi * block_q + block_q - 1 + q_offset)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (bq, bk)
        if causal:
            mask = k_pos[None, :] <= q_pos[:, None]
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bh(
    q: jnp.ndarray,  # (BH, S, D)
    k: jnp.ndarray,  # (BH, T, D)
    v: jnp.ndarray,  # (BH, T, D)
    *,
    causal: bool = True,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    BH, S, D = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    grid = (BH, S // block_q, T // block_k)
    sm_scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
        kv_blocks=T // block_k,
        q_offset=T - S if causal else 0,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),  # fp32 accumulator
            pltpu.VMEM((block_q,), jnp.float32),  # running max m
            pltpu.VMEM((block_q,), jnp.float32),  # running denom l
        ],
        interpret=interpret,
    )(q, k, v)
