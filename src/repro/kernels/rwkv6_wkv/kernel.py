"""Chunked RWKV-6 WKV Pallas TPU kernel.

GPU RWKV kernels are per-token CUDA loops (one thread per channel).  The TPU
adaptation reformulates the recurrence into *chunked matrix form* so the MXU
does the work (see models/rwkv6.wkv_scan_chunked):

    intra-chunk:  y += tril_strict(r~ k~^T) v  + diag bonus
    inter-chunk:  y += r~ . S_carry
    state:        S <- diag(P_tot) S + (k * P_tot/P_incl)^T v

Grid ``(BH, n_chunks)``: TPU grids iterate the trailing dim sequentially, so
the (D, D) fp32 state lives in VMEM scratch and is carried across chunk
iterations of the same head — no HBM round-trip for the state.  Chunk length
rides the sublane dim; D (=64 for rwkv6-7b, padded to 128 lanes by Mosaic)
the lane dim.  fp32 throughout (decay ratios are exp-of-cumsum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 32


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sT_ref, s_ref, *,
                chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)  # (c, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (D,) broadcast per head
    s = s_ref[...]

    logw = jnp.log(jnp.maximum(w, 1e-12))
    p_incl = jnp.exp(jnp.cumsum(logw, axis=0))  # (c, D) prod_{s<=t}
    p_excl = p_incl / w
    p_tot = p_incl[-1]

    r_t = r * p_excl
    k_s = k / p_incl
    att = jax.lax.dot_general(
        r_t, k_s, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (c, c)
    ti = jax.lax.iota(jnp.int32, chunk)
    tri = ti[:, None] > ti[None, :]  # strictly lower triangular
    att = jnp.where(tri, att, 0.0)
    diag = jnp.sum(r * (u[None, :] * k), axis=-1)  # (c,)
    y = jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y = y + diag[:, None] * v
    y = y + jax.lax.dot_general(
        r_t, s, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0] = y.astype(o_ref.dtype)

    kw = k * (p_tot[None, :] / p_incl)
    s_new = p_tot[:, None] * s + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _final():
        sT_ref[0] = s_new


def wkv_chunked(
    r: jnp.ndarray,  # (BH, S, D) fp32
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,  # (BH, D)
    *,
    chunk: int = CHUNK,
    interpret: bool = False,
):
    BH, S, D = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    grid = (BH, n_chunks)
    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    y, sT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, D), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, D, D), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, D), jnp.float32),
            jax.ShapeDtypeStruct((BH, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, sT
