"""Jit-able wrapper matching the model-layer calling convention
(B, S, H, D) + u (H, D) + s0 (B, H, D, D)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import CHUNK, wkv_chunked


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(
    r: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,  # (H, D)
    s0: jnp.ndarray,  # (B, H, D, D) — kernel assumes zero init; nonzero s0
    # is folded in via a rank-1 correction outside the kernel.
    chunk: int = CHUNK,
    interpret: bool = False,
):
    B, S, H, D = r.shape
    to_bh = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, S, D).astype(jnp.float32)
    rb, kb, vb, wb = map(to_bh, (r, k, v, w))
    ub = jnp.broadcast_to(u.astype(jnp.float32)[None], (B, H, D)).reshape(B * H, D)
    pad = (-S) % min(chunk, S) if S else 0
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        rb, kb, vb = z(rb), z(kb), z(vb)
        wb = jnp.pad(wb, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    y, sT = wkv_chunked(rb, kb, vb, wb, ub, chunk=min(chunk, S + pad), interpret=interpret)
    y = y[:, :S].reshape(B, H, S, D).transpose(0, 2, 1, 3)
    sT = sT.reshape(B, H, D, D)
    # fold a nonzero initial state in analytically:
    #   y += (r * P_excl) . s0 ; sT += diag(P_tot) s0
    nonzero = jnp.any(s0 != 0)

    def fold(_):
        logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-12))
        p_incl = jnp.exp(jnp.cumsum(logw, axis=1))  # (B,S,H,D)
        p_excl = p_incl / w.astype(jnp.float32)
        y2 = y + jnp.einsum("bshk,bhkv->bshv", r.astype(jnp.float32) * p_excl, s0)
        sT2 = sT + p_incl[:, -1].transpose(0, 1, 2)[..., None] * s0
        return y2, sT2

    y, sT = jax.lax.cond(nonzero, fold, lambda _: (y, sT), operand=None)
    return y, sT
