"""Pure-jnp oracle for the RWKV-6 WKV recurrence (sequential scan).

    y_t = r_t . S_{t-1} + (r_t . (u*k_t)) v_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, w, u, s0):
    """r,k,v,w: (BH, S, D) fp32; u: (BH, D); s0: (BH, D, D).
    Returns y (BH, S, D), sT (BH, D, D)."""

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (BH, D)
        bonus = jnp.einsum("bk,bk->b", r_t, u * k_t)
        y = jnp.einsum("bk,bkv->bv", r_t, s) + bonus[:, None] * v_t
        s = w_t[..., None] * s + jnp.einsum("bk,bv->bkv", k_t, v_t)
        return s, y

    xs = tuple(a.swapaxes(0, 1) for a in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), sT
