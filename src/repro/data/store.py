"""Object-store abstraction: the paper's storage axis (scratch vs S3).

``ObjectStore`` is the minimal S3-like interface (GET/PUT/LIST).  Concrete
backends:

* :class:`InMemoryStore`       — dict-backed "scratch" (fast local path).
* :class:`LocalFSStore`        — directory of files ("scratch" on real disks).
* :class:`SimulatedS3Store`    — wraps any store with a calibrated network
  model: per-GET lognormal latency, per-connection bandwidth, an aggregate
  NIC cap and a bounded connection pool.  Reproduces the latency-vs-
  concurrency phenomenology of real S3 on CPU-only CI.  A real S3 backend
  (boto3) would subclass ``ObjectStore`` with the same interface.
* :class:`CachedStore` / :class:`DiskCacheStore` / :class:`TieredCacheStore`
  — the cache tiers (Varnish analogue, paper §2.4), implemented in
  :mod:`repro.data.cache` and re-exported here for back-compat.

Both sync ``get`` and async ``aget`` are provided; the simulated network
sleeps with ``time.sleep`` (releases the GIL — I/O-like) or ``asyncio.sleep``.
"""
from __future__ import annotations

import asyncio
import hashlib
import os
import random
import threading
import time
from abc import ABC, abstractmethod
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.config import StoreConfig


class StoreError(RuntimeError):
    pass


class KeyNotFound(StoreError):
    pass


class TransientStoreError(StoreError):
    """Retryable failure (injected by the failure model)."""


class ObjectStore(ABC):
    """S3-like blob interface."""

    @abstractmethod
    def get(self, key: str) -> bytes: ...

    @abstractmethod
    def put(self, key: str, data: bytes) -> None: ...

    @abstractmethod
    def list_keys(self, prefix: str = "") -> List[str]: ...

    def size(self, key: str) -> int:
        return len(self.get(key))

    async def aget(self, key: str) -> bytes:
        """Async GET; default delegates to a thread so sync stores still work."""
        return await asyncio.get_running_loop().run_in_executor(None, self.get, key)

    def close(self) -> None:
        pass


class InMemoryStore(ObjectStore):
    def __init__(self) -> None:
        self._data: Dict[str, bytes] = {}
        self._lock = threading.Lock()

    def get(self, key: str) -> bytes:
        with self._lock:
            try:
                return self._data[key]
            except KeyError:
                raise KeyNotFound(key) from None

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._data[key] = bytes(data)

    def list_keys(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))

    def size(self, key: str) -> int:
        with self._lock:
            try:
                return len(self._data[key])
            except KeyError:
                raise KeyNotFound(key) from None


class LocalFSStore(ObjectStore):
    """Directory-of-files store ("scratch" local drives in the paper)."""

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        safe = key.replace("/", "__")
        return os.path.join(self.root, safe)

    def get(self, key: str) -> bytes:
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise KeyNotFound(key) from None

    def put(self, key: str, data: bytes) -> None:
        tmp = self._path(key) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, self._path(key))

    def list_keys(self, prefix: str = "") -> List[str]:
        safe_prefix = prefix.replace("/", "__")
        return sorted(
            k.replace("__", "/")
            for k in os.listdir(self.root)
            if k.startswith(safe_prefix) and not k.endswith(".tmp")
        )

    def size(self, key: str) -> int:
        try:
            return os.path.getsize(self._path(key))
        except FileNotFoundError:
            raise KeyNotFound(key) from None


# ---------------------------------------------------------------------------
# Simulated S3
# ---------------------------------------------------------------------------


@dataclass
class StoreStats:
    gets: int = 0
    bytes_read: int = 0
    failures: int = 0
    total_wait_s: float = 0.0

    def snapshot(self) -> "StoreStats":
        return StoreStats(self.gets, self.bytes_read, self.failures, self.total_wait_s)


class SimulatedS3Store(ObjectStore):
    """Network model around a backing store.

    GET time = connection-pool wait + lognormal latency + size / bandwidth,
    where bandwidth = min(per-connection bw, NIC bw / concurrent transfers).
    Deterministic per (seed, key, attempt) so experiments are reproducible.

    Two multi-process extensions (both inert by default):

    * ``shared_active`` — a duck-typed counter (``add(delta) -> int``,
      ``value() -> int``; e.g. :class:`repro.core.coord.SharedCounter`) that
      several *processes* increment for their in-flight transfers, modelling
      many loader hosts behind ONE physical NIC: the bandwidth divisor
      becomes the fleet-wide active count instead of this process's.
    * ``overload_penalty`` — congestion-collapse exponent: when the active
      transfer count exceeds the NIC's saturation point
      (``nic_bandwidth / bandwidth_per_conn``), service time additionally
      scales by ``oversubscription ** overload_penalty`` (queueing /
      bufferbloat tail).  With the default 0 extra concurrency never hurts
      throughput, which is exactly the monotone regime where uncoordinated
      autotuners look harmless; a positive penalty reproduces the collapse
      that multi-host coordination exists to prevent.
    """

    def __init__(
        self,
        base: ObjectStore,
        latency_mean_s: float = 0.08,
        latency_sigma: float = 0.5,
        bandwidth_per_conn: float = 25e6,
        nic_bandwidth: float = 1.2e9,
        max_connections: int = 256,
        failure_rate: float = 0.0,
        seed: int = 0,
        time_scale: float = 1.0,
        overload_penalty: float = 0.0,
        shared_active=None,
    ) -> None:
        self.base = base
        self.latency_mean_s = latency_mean_s
        self.latency_sigma = latency_sigma
        self.bandwidth_per_conn = bandwidth_per_conn
        self.nic_bandwidth = nic_bandwidth
        self.max_connections = max_connections
        self.failure_rate = failure_rate
        self.seed = seed
        self.time_scale = time_scale
        self.overload_penalty = overload_penalty
        self.shared_active = shared_active
        self._sem = threading.BoundedSemaphore(max_connections)
        self._async_sems: Dict[int, asyncio.Semaphore] = {}
        self._active = 0
        self._active_lock = threading.Lock()
        self._stats = StoreStats()
        self._stats_lock = threading.Lock()
        self._attempt: Dict[str, int] = {}
        self._attempt_lock = threading.Lock()

    # -- deterministic stochastic model -------------------------------------
    def _next_attempt(self, key: str) -> int:
        with self._attempt_lock:
            n = self._attempt.get(key, 0)
            self._attempt[key] = n + 1
            return n

    def _rng(self, key: str, attempt: int) -> random.Random:
        h = hashlib.blake2b(
            f"{self.seed}:{key}:{attempt}".encode(), digest_size=8
        ).digest()
        return random.Random(int.from_bytes(h, "little"))

    def _sample(self, key: str, size: int) -> tuple[float, bool]:
        """Return (service time seconds, fail?) for one GET."""
        attempt = self._next_attempt(key)
        rng = self._rng(key, attempt)
        fail = rng.random() < self.failure_rate
        lat = rng.lognormvariate(0.0, self.latency_sigma) * self.latency_mean_s
        if self.shared_active is not None:
            active = max(self.shared_active.value(), 1)
        else:
            with self._active_lock:
                active = max(self._active, 1)
        bw = min(self.bandwidth_per_conn, self.nic_bandwidth / active)
        xfer = size / bw
        dt = lat + xfer
        if self.overload_penalty:
            saturation = max(self.nic_bandwidth / self.bandwidth_per_conn, 1.0)
            if active > saturation:
                dt *= (active / saturation) ** self.overload_penalty
        return dt * self.time_scale, fail

    def _enter(self) -> None:
        with self._active_lock:
            self._active += 1
        if self.shared_active is not None:
            self.shared_active.add(1)

    def _exit(self) -> None:
        with self._active_lock:
            self._active -= 1
        if self.shared_active is not None:
            self.shared_active.add(-1)

    def _bump(self, size: int, wait: float, failed: bool) -> None:
        with self._stats_lock:
            self._stats.gets += 1
            self._stats.total_wait_s += wait
            if failed:
                self._stats.failures += 1
            else:
                self._stats.bytes_read += size

    # -- sync path -----------------------------------------------------------
    def get(self, key: str) -> bytes:
        with self._sem:  # connection pool
            self._enter()
            try:
                data = self.base.get(key)
                dt, fail = self._sample(key, len(data))
                time.sleep(dt)
                self._bump(len(data), dt, fail)
                if fail:
                    raise TransientStoreError(f"simulated GET failure for {key}")
                return data
            finally:
                self._exit()

    # -- async path ----------------------------------------------------------
    def _loop_sem(self) -> asyncio.Semaphore:
        loop = asyncio.get_running_loop()
        key = id(loop)
        if key not in self._async_sems:
            self._async_sems[key] = asyncio.Semaphore(self.max_connections)
        return self._async_sems[key]

    async def aget(self, key: str) -> bytes:
        async with self._loop_sem():
            self._enter()
            try:
                data = self.base.get(key)  # backing read is in-memory/fast
                dt, fail = self._sample(key, len(data))
                await asyncio.sleep(dt)
                self._bump(len(data), dt, fail)
                if fail:
                    raise TransientStoreError(f"simulated GET failure for {key}")
                return data
            finally:
                self._exit()

    def put(self, key: str, data: bytes) -> None:
        self.base.put(key, data)

    def list_keys(self, prefix: str = "") -> List[str]:
        return self.base.list_keys(prefix)

    def size(self, key: str) -> int:
        return self.base.size(key)

    @property
    def stats(self) -> StoreStats:
        with self._stats_lock:
            return self._stats.snapshot()


# ---------------------------------------------------------------------------
# Caches — implemented in repro.data.cache; re-exported here for back-compat
# ---------------------------------------------------------------------------

from repro.core.coord import SharedDiskJournal  # noqa: E402
from repro.data.cache import (  # noqa: E402
    CachedStore,
    DiskCacheStore,
    DiskTierCache,
    MemoryTierCache,
    TieredCacheStore,
    make_admission,
)

# TieredCacheStore implements the full ObjectStore protocol but cannot inherit
# from it (repro.data.cache must not import this module back)
ObjectStore.register(TieredCacheStore)


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------


def build_store(cfg: StoreConfig, base: Optional[ObjectStore] = None,
                time_scale: float = 1.0, seed: int = 0,
                tracer=None) -> ObjectStore:
    """Assemble the store stack described by a StoreConfig.

    ``tracer`` (a ``repro.core.tracing.Tracer``) makes the cache tiers emit
    per-GET ``cache_get`` spans.  It must be passed explicitly: the loader
    deliberately never rebinds a store's tracer (the store may be shared by
    several loaders), so omitting it means no cache spans."""
    if base is None:
        if cfg.kind == "localfs":
            base = LocalFSStore(cfg.root)
        else:
            base = InMemoryStore()
    store: ObjectStore = base
    if cfg.kind == "s3sim":
        store = SimulatedS3Store(
            store,
            latency_mean_s=cfg.latency_mean_s,
            latency_sigma=cfg.latency_sigma,
            bandwidth_per_conn=cfg.bandwidth_per_conn,
            nic_bandwidth=cfg.nic_bandwidth,
            max_connections=cfg.max_connections,
            failure_rate=cfg.failure_rate,
            seed=seed,
            time_scale=time_scale,
            overload_penalty=cfg.overload_penalty,
        )
    cache = cfg.cache
    if cache.dir and cache.memory_bytes:
        # both tiers configured: a single two-tier store (memory over disk)
        store = TieredCacheStore(
            store,
            memory=MemoryTierCache(cache.memory_bytes, shards=cache.shards),
            disk=_build_disk_tier(cfg),
            admission_max_item_bytes=cache.admission_max_item_bytes,
        )
    elif cache.dir:
        store = TieredCacheStore(
            store,
            disk=_build_disk_tier(cfg),
            admission_max_item_bytes=cache.admission_max_item_bytes,
        )
    elif cache.memory_bytes:
        store = CachedStore(store, cache.memory_bytes)
    if tracer is not None and isinstance(store, TieredCacheStore):
        store.tracer = tracer
    return store


def _build_disk_tier(cfg: StoreConfig) -> DiskTierCache:
    """Disk tier per StoreConfig.cache, including the multi-host coordination
    mode (``coord``): "" = private in-process accounting (single host),
    "journal" = shared byte journal under ``dir/.coord``, "shard" =
    ``host_shard``-partitioned keyspace (per-host capacity)."""
    cache = cfg.cache
    journal = None
    shard = None
    if cache.coord == "journal":
        journal = SharedDiskJournal(cache.dir, cache.disk_bytes)
    elif cache.coord == "shard":
        shard = (cache.coord_host_id, cache.coord_num_hosts)
    elif cache.coord:
        raise ValueError(
            f"unknown cache coord {cache.coord!r}; known: '', 'journal', 'shard'"
        )
    return DiskTierCache(
        cache.dir,
        cache.disk_bytes,
        make_admission(cache.admission, cache.admission_max_item_bytes),
        journal=journal,
        shard=shard,
    )
