"""Synthetic ImageNet-like dataset.

The paper trains ResNet-18 on ImageNet ILSVRC-2012 (avg item ~115 kB, avg
dims 469x387).  CI has no ImageNet, so we provide two equivalent sources:

* :func:`build_synthetic_imagenet` — materializes N encoded images into any
  ObjectStore (used for small benchmark datasets).
* :class:`SyntheticImageStore` — generates the byte blob for a key *on
  demand*, deterministically from the key hash, so a 15 000-item "dataset"
  costs no RAM up front.  This is the default backing store for benchmarks;
  wrapped in SimulatedS3Store it behaves exactly like remote blobs.

Sizes are drawn lognormally around ``avg_kb`` to match the paper's
size-throughput accounting (Mbit/s).
"""
from __future__ import annotations

import hashlib
from typing import List, Optional

import numpy as np

from repro.data.codec import encode_image
from repro.data.store import InMemoryStore, KeyNotFound, ObjectStore

DEFAULT_PREFIX = "imagenet/train/"
NUM_CLASSES = 1000


def item_key(index: int, prefix: str = DEFAULT_PREFIX) -> str:
    return f"{prefix}{index:08d}.rimg"


def _rng_for(seed: int, key: str) -> np.random.Generator:
    h = hashlib.blake2b(f"{seed}:{key}".encode(), digest_size=8).digest()
    return np.random.default_rng(int.from_bytes(h, "little"))


def synth_image_bytes(seed: int, key: str, avg_kb: float = 115.0,
                      size_sigma: float = 0.35) -> bytes:
    """Deterministically synthesize one encoded image blob for ``key``."""
    rng = _rng_for(seed, key)
    target = rng.lognormal(0.0, size_sigma) * avg_kb * 1024.0
    # uncompressed uint8 HWC: pick H,W near the paper's 469x387 aspect so that
    # H*W*3 ~= target bytes.
    aspect = 469.0 / 387.0
    h = max(32, int(np.sqrt(target / 3.0 / aspect)))
    w = max(32, int(h * aspect))
    # cheap-but-nontrivial content: low-freq gradient + noise
    yy = np.linspace(0, 1, h, dtype=np.float32)[:, None]
    xx = np.linspace(0, 1, w, dtype=np.float32)[None, :]
    base = (yy * 127 + xx * 127)[..., None]
    noise = rng.integers(0, 64, size=(h, w, 3), dtype=np.uint8)
    px = np.clip(base + noise, 0, 255).astype(np.uint8)
    label = int(rng.integers(0, NUM_CLASSES))
    return encode_image(px, label, compress=0)


class SyntheticImageStore(ObjectStore):
    """Generates image blobs on GET; deterministic; O(1) memory."""

    def __init__(self, num_items: int, seed: int = 0, avg_kb: float = 115.0,
                 prefix: str = DEFAULT_PREFIX, size_sigma: float = 0.35) -> None:
        self.num_items = num_items
        self.seed = seed
        self.avg_kb = avg_kb
        self.prefix = prefix
        self.size_sigma = size_sigma

    def _check(self, key: str) -> None:
        if not key.startswith(self.prefix):
            raise KeyNotFound(key)
        try:
            idx = int(key[len(self.prefix):].split(".")[0])
        except ValueError:
            raise KeyNotFound(key) from None
        if not (0 <= idx < self.num_items):
            raise KeyNotFound(key)

    def get(self, key: str) -> bytes:
        self._check(key)
        return synth_image_bytes(self.seed, key, self.avg_kb, self.size_sigma)

    def put(self, key: str, data: bytes) -> None:
        raise StoreReadOnly("SyntheticImageStore is read-only")

    def list_keys(self, prefix: str = "") -> List[str]:
        keys = [item_key(i, self.prefix) for i in range(self.num_items)]
        return [k for k in keys if k.startswith(prefix)]


class StoreReadOnly(RuntimeError):
    pass


def build_synthetic_imagenet(
    store: Optional[ObjectStore] = None,
    num_items: int = 1024,
    seed: int = 0,
    avg_kb: float = 115.0,
    prefix: str = DEFAULT_PREFIX,
) -> ObjectStore:
    """Materialize ``num_items`` encoded images into ``store``."""
    if store is None:
        store = InMemoryStore()
    for i in range(num_items):
        key = item_key(i, prefix)
        store.put(key, synth_image_bytes(seed, key, avg_kb))
    return store
