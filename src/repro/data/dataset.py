"""Dataset layer (paper Fig. 1 bottom lane): maps an index to one training
item fetched from an ObjectStore, then decodes + augments it.

``sim_decode_s_per_mb`` models the libjpeg decode cost (GIL-releasing C
work) with a byte-proportional sleep, the same simulation philosophy as
SimulatedS3Store models the network: the paper's ~6 ms/115 kB ImageNet JPEG
decode is ~52 ms/MB.  It is what makes local ("scratch") items cost
milliseconds and what within-batch parallelism can overlap on scratch
(paper Fig. 14's 3x scratch batch-load reduction).  Default 0 (off).

The Dataset is deliberately isolated from the loader (paper §3.2) — it can be
driven directly (``get_random_item``) for the Fig. 12 pool-size sweep.  Both a
sync ``__getitem__`` and an async ``aget_item`` are provided so the Asyncio
fetcher can issue truly concurrent GETs.
"""
from __future__ import annotations

import hashlib
import time
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.core.tracing import GET_ITEM, NULL_TRACER, Tracer
from repro.data import codec
from repro.data.augment import imagenet_transform, imagenet_transform_raw
from repro.data.imagenet_synth import item_key
from repro.data.store import ObjectStore

Item = Dict[str, np.ndarray]


class MapDataset:
    """Minimal map-style dataset protocol.

    Datasets that can separate their storage read from their CPU work
    additionally expose the *split* path (``supports_split() -> True``)::

        raw     = get_raw(i)            # IO only: bytes off the store
        decoded = decode_raw(raw, i)    # CPU: codec work
        item    = augment_item(decoded, i)  # CPU: augmentation / normalize

    ``__getitem__`` must equal ``augment_item(decode_raw(get_raw(i), i), i)``
    bit-for-bit — the staged pipeline (:mod:`repro.core.pipeline`) runs the
    three stages on different executors and relies on that equivalence for
    its ``reorder="strict"`` guarantee.  Datasets that cannot split keep the
    default ``supports_split() -> False`` and the pipeline falls back to the
    monolithic ``__getitem__`` on its IO executor.

    **Picklability contract** (``LoaderConfig.cpu_executor="process"``): the
    pipeline's process CPU stage ships one pickled copy of the dataset to
    each spawn-based worker, where ONLY ``decode_raw`` / ``augment_item``
    run — ``get_raw`` always executes in the parent's IO stage.  A split
    dataset is process-capable iff it pickles with its decode/augment state
    intact; members those stages never touch (the store, the tracer) may be
    dropped on pickle, which is exactly what :class:`ImageDataset` and
    :class:`TokenDataset` do via ``__getstate__``.
    """

    def __len__(self) -> int:
        raise NotImplementedError

    def __getitem__(self, index: int) -> Item:
        raise NotImplementedError

    async def aget_item(self, index: int) -> Item:
        """Async variant; default falls back to the sync path."""
        return self[index]

    def set_epoch(self, epoch: int) -> None:
        """Hook for per-epoch augmentation determinism."""

    # -- split (staged-pipeline) path ---------------------------------------
    def supports_split(self) -> bool:
        """Whether the get_raw/decode_raw/augment_item stages are available."""
        return False

    def get_raw(self, index: int) -> bytes:
        """Storage read only — no decode, no augmentation."""
        raise NotImplementedError

    async def aget_raw(self, index: int) -> bytes:
        """Async variant of :meth:`get_raw`; default wraps the sync path."""
        return self.get_raw(index)

    def decode_raw(self, raw: bytes, index: int):
        """Codec stage: bytes -> decoded intermediate (dataset-defined)."""
        raise NotImplementedError

    def augment_item(self, decoded, index: int) -> Item:
        """Augment stage: decoded intermediate -> final Item.  Identity by
        default for datasets whose decode already yields the Item."""
        return decoded


def _aug_rng(seed: int, epoch: int, index: int) -> np.random.Generator:
    h = hashlib.blake2b(f"aug:{seed}:{epoch}:{index}".encode(), digest_size=8).digest()
    return np.random.default_rng(int.from_bytes(h, "little"))


class _StripStoreOnPickle:
    """Mixin implementing the process-CPU-stage picklability contract: a
    pickled copy drops the store (locks, sockets, open files — and never
    needed: ``get_raw`` runs in the parent) and the tracer (holds a lock;
    worker-side spans are shipped home by the stage itself)."""

    def __getstate__(self) -> Dict:
        state = dict(self.__dict__)
        state["store"] = None
        state["tracer"] = None
        return state

    def __setstate__(self, state: Dict) -> None:
        self.__dict__.update(state)
        if self.__dict__.get("tracer") is None:
            self.tracer = NULL_TRACER


class ImageDataset(_StripStoreOnPickle, MapDataset):
    """ImageNet-style dataset over an ObjectStore (paper's setup).

    ``epilogue`` picks where the transform's cast/normalize/layout tail runs:
    ``"host"`` (default) emits normalized f32 CHW images, the paper's plain
    transform; ``"device"`` stops after crop+flip and emits uint8 HWC —
    the training loop is then expected to run the fused on-device epilogue
    (:func:`repro.kernels.ingest_norm.ops.make_ingest_fn`) after H2D, so
    every host-side copy (shm slot, staging buffer, PCIe) moves 4x fewer
    bytes.  RNG consumption is identical, so the two paths see the same
    crops/flips.
    """

    def __init__(
        self,
        store: ObjectStore,
        num_items: int,
        prefix: str = "imagenet/train/",
        out_size: int = 224,
        augment: bool = True,
        seed: int = 0,
        tracer: Tracer = NULL_TRACER,
        sim_decode_s_per_mb: float = 0.0,
        epilogue: str = "host",
    ) -> None:
        if epilogue not in ("host", "device"):
            raise ValueError(f"epilogue must be 'host' or 'device', got {epilogue!r}")
        self.store = store
        self.num_items = num_items
        self.prefix = prefix
        self.out_size = out_size
        self.augment = augment
        self.seed = seed
        self.tracer = tracer
        self.sim_decode_s_per_mb = sim_decode_s_per_mb
        self.epilogue = epilogue
        self._epoch = 0

    def set_epoch(self, epoch: int) -> None:
        self._epoch = epoch

    def __len__(self) -> int:
        return self.num_items

    # -- split path (one stage per pipeline executor) ------------------------
    def supports_split(self) -> bool:
        return True

    def get_raw(self, index: int) -> bytes:
        return self.store.get(item_key(index, self.prefix))

    async def aget_raw(self, index: int) -> bytes:
        return await self.store.aget(item_key(index, self.prefix))

    def decode_raw(self, raw: bytes, index: int) -> Tuple[codec.ImageRecord, int]:
        if self.sim_decode_s_per_mb:
            # emulated C-decoder cost: sleeps release the GIL like libjpeg
            time.sleep(self.sim_decode_s_per_mb * len(raw) / 1e6)
        return codec.decode_image(raw), len(raw)

    def augment_item(self, decoded: Tuple[codec.ImageRecord, int], index: int) -> Item:
        rec, nbytes = decoded
        device_tail = self.epilogue == "device"
        if self.augment:
            rng = _aug_rng(self.seed, self._epoch, index)
            if device_tail:
                img = imagenet_transform_raw(rec.pixels, rng, self.out_size)
            else:
                img = imagenet_transform(rec.pixels, rng, self.out_size)
        else:
            side = self.out_size
            px = rec.pixels[:side, :side]
            pad_h, pad_w = side - px.shape[0], side - px.shape[1]
            if pad_h > 0 or pad_w > 0:
                px = np.pad(px, ((0, max(pad_h, 0)), (0, max(pad_w, 0)), (0, 0)))
            if device_tail:
                img = np.ascontiguousarray(px)
            else:
                img = np.ascontiguousarray(px.transpose(2, 0, 1)).astype(np.float32) / 255.0
        return {
            "image": img,
            "label": np.int32(rec.label),
            "nbytes": np.int64(nbytes),
        }

    def _decode(self, raw: bytes, index: int) -> Item:
        return self.augment_item(self.decode_raw(raw, index), index)

    def __getitem__(self, index: int) -> Item:
        with self.tracer.span(GET_ITEM, index=index):
            raw = self.get_raw(index)
            return self._decode(raw, index)

    async def aget_item(self, index: int) -> Item:
        with self.tracer.span(GET_ITEM, index=index):
            raw = await self.aget_raw(index)
            return self._decode(raw, index)

    def get_random_item(self, rng: np.random.Generator) -> Item:
        """Paper §3.2 Dataset-layer benchmark access pattern."""
        return self[int(rng.integers(0, self.num_items))]


class TokenDataset(_StripStoreOnPickle, MapDataset):
    """Packed-sequence LM dataset: one object = one packed token sequence."""

    def __init__(
        self,
        store: ObjectStore,
        num_items: int,
        seq_len: int,
        prefix: str = "tokens/train/",
        tracer: Tracer = NULL_TRACER,
    ) -> None:
        self.store = store
        self.num_items = num_items
        self.seq_len = seq_len
        self.prefix = prefix
        self.tracer = tracer

    def key(self, index: int) -> str:
        return f"{self.prefix}{index:08d}.rtok"

    def __len__(self) -> int:
        return self.num_items

    def _decode(self, raw: bytes) -> Item:
        toks = codec.decode_tokens(raw)
        assert toks.shape[0] >= self.seq_len + 1, "sequence too short"
        return {
            "tokens": toks[: self.seq_len].astype(np.int32),
            "targets": toks[1 : self.seq_len + 1].astype(np.int32),
            "nbytes": np.int64(len(raw)),
        }

    # -- split path (augment stage is the identity: tokens have none) --------
    def supports_split(self) -> bool:
        return True

    def get_raw(self, index: int) -> bytes:
        return self.store.get(self.key(index))

    async def aget_raw(self, index: int) -> bytes:
        return await self.store.aget(self.key(index))

    def decode_raw(self, raw: bytes, index: int) -> Item:
        return self._decode(raw)

    def __getitem__(self, index: int) -> Item:
        with self.tracer.span(GET_ITEM, index=index):
            return self._decode(self.get_raw(index))

    async def aget_item(self, index: int) -> Item:
        with self.tracer.span(GET_ITEM, index=index):
            return self._decode(await self.aget_raw(index))


class SyntheticTokenDataset(MapDataset):
    """Deterministic on-the-fly token sequences (no store; for model tests)."""

    def __init__(self, num_items: int, seq_len: int, vocab_size: int, seed: int = 0) -> None:
        self.num_items = num_items
        self.seq_len = seq_len
        self.vocab_size = vocab_size
        self.seed = seed

    def __len__(self) -> int:
        return self.num_items

    def __getitem__(self, index: int) -> Item:
        rng = np.random.default_rng(self.seed * 1_000_003 + index)
        toks = rng.integers(0, self.vocab_size, size=self.seq_len + 1, dtype=np.int32)
        return {"tokens": toks[:-1], "targets": toks[1:], "nbytes": np.int64(toks.nbytes)}


class SpinDataset(MapDataset):
    """Split-path dataset whose decode stage genuinely HOLDS the GIL.

    The simulated decoders elsewhere model C-library work with
    ``time.sleep`` (which releases the GIL, like libjpeg) — fine for IO/CPU
    overlap studies, but it *understates* GIL contention, the very ceiling
    the paper's Appendix A.4 measures.  This dataset's decode is a pure-
    Python byte-crunch busy loop: deterministic output (so strict-reorder
    bit-identity claims hold across executors), ~``0.17 ms`` per 2048-byte
    round, and no escape from the interpreter — the regime where the
    pipeline's process CPU stage is the only way past single-core decode
    speed.  ``io_s`` adds a GIL-releasing sleep in ``get_raw`` to stand in
    for storage latency.  Fully picklable (no store, no locks), so it is
    also the reference process-capable dataset for tests and
    ``bench_procpool``.
    """

    def __init__(
        self,
        num_items: int,
        item_bytes: int = 2048,
        spin_rounds: int = 8,
        io_s: float = 0.0,
        seed: int = 0,
    ) -> None:
        self.num_items = num_items
        self.item_bytes = item_bytes
        self.spin_rounds = spin_rounds
        self.io_s = io_s
        self.seed = seed

    def __len__(self) -> int:
        return self.num_items

    # -- split path -----------------------------------------------------------
    def supports_split(self) -> bool:
        return True

    def get_raw(self, index: int) -> bytes:
        if self.io_s:
            time.sleep(self.io_s)  # releases the GIL, like a socket read
        rng = np.random.default_rng(self.seed * 1_000_003 + index)
        return rng.bytes(self.item_bytes)

    def decode_raw(self, raw: bytes, index: int) -> Tuple[int, int]:
        acc = index & 0xFFFFFFFF
        for _ in range(self.spin_rounds):
            for b in raw:  # pure Python: holds the GIL for the whole decode
                acc = (acc * 1103515245 + b) & 0xFFFFFFFF
        return acc, len(raw)

    def augment_item(self, decoded: Tuple[int, int], index: int) -> Item:
        acc, nbytes = decoded
        return {
            "x": np.int64(acc),
            "label": np.int32(index),
            "nbytes": np.int64(nbytes),
        }

    def __getitem__(self, index: int) -> Item:
        return self.augment_item(self.decode_raw(self.get_raw(index), index), index)


def build_token_store(
    store: ObjectStore,
    num_items: int,
    seq_len: int,
    vocab_size: int,
    prefix: str = "tokens/train/",
    seed: int = 0,
) -> None:
    """Materialize packed token sequences into a store."""
    for i in range(num_items):
        rng = np.random.default_rng(seed * 1_000_003 + i)
        toks = rng.integers(0, vocab_size, size=seq_len + 1, dtype=np.int32)
        store.put(f"{prefix}{i:08d}.rtok", codec.encode_tokens(toks))


def collate(items: Sequence[Item]) -> Item:
    """Stack a list of items into a batch (numpy; device_put happens later)."""
    assert items, "empty batch"
    out: Item = {}
    for k in items[0]:
        vals = [it[k] for it in items]
        out[k] = np.stack(vals) if np.ndim(vals[0]) else np.asarray(vals)
    return out
