"""Item codecs.

The paper stores JPEG images.  We mimic the *size distribution* (~115 kB
average) and a realistic decode cost with a simple self-describing binary
format: a fixed header + (optionally zlib-compressed) uint8 pixel payload.
Token shards for the LM architectures are raw int32 arrays with a header.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass

import numpy as np

_IMG_MAGIC = b"RIMG"
_TOK_MAGIC = b"RTOK"


@dataclass
class ImageRecord:
    pixels: np.ndarray  # (H, W, C) uint8
    label: int


def encode_image(pixels: np.ndarray, label: int, compress: int = 0) -> bytes:
    assert pixels.dtype == np.uint8 and pixels.ndim == 3
    h, w, c = pixels.shape
    payload = pixels.tobytes()
    if compress:
        payload = zlib.compress(payload, compress)
    header = _IMG_MAGIC + struct.pack("<IIIIB", h, w, c, label, 1 if compress else 0)
    return header + payload


def decode_image(data: bytes) -> ImageRecord:
    if data[:4] != _IMG_MAGIC:
        raise ValueError("not an RIMG record")
    h, w, c, label, compressed = struct.unpack("<IIIIB", data[4:21])
    payload = data[21:]
    if compressed:
        payload = zlib.decompress(payload)
    px = np.frombuffer(payload, dtype=np.uint8).reshape(h, w, c)
    return ImageRecord(px, label)


def encode_tokens(tokens: np.ndarray) -> bytes:
    assert tokens.dtype == np.int32 and tokens.ndim == 1
    return _TOK_MAGIC + struct.pack("<I", tokens.shape[0]) + tokens.tobytes()


def decode_tokens(data: bytes) -> np.ndarray:
    if data[:4] != _TOK_MAGIC:
        raise ValueError("not an RTOK record")
    (n,) = struct.unpack("<I", data[4:8])
    return np.frombuffer(data[8 : 8 + 4 * n], dtype=np.int32)
