"""Tiered cache subsystem — the hot tier behind the paper's 12x S3 win.

The paper's Varnish cache (§2.4) only pays off when the hot tier absorbs
repeat reads; this module makes that tier a first-class, *tunable* subsystem:

* :class:`MemoryTierCache` — sharded, lock-striped in-process LRU bounded by
  bytes.  Shard count 1 gives exact global LRU (the legacy ``CachedStore``
  semantics); more shards trade strict LRU for reduced lock contention.
* :class:`DiskTierCache`  — **bounded** on-disk tier: atomic tmp+rename
  writes, LRU eviction by bytes, a pluggable admission policy, and crash
  recovery (orphaned ``*.tmp*`` files older than ``tmp_grace_s`` are purged
  — a *fresh* tmp belongs to a live writer in another process — and
  surviving entries re-indexed, oldest-mtime first, on init).  Capacity is
  *reserved before the write*, so parallel writers can never overshoot
  ``capacity_bytes``.  Two multi-host modes (``repro.core.coord``) make the
  tier safe when several processes/hosts share one directory: ``journal``
  replaces the in-process index with a cross-process ``fcntl``-locked byte
  journal, and ``shard`` partitions the keyspace with
  :func:`~repro.core.coord.host_shard` (each host accounts only its own
  shard but opportunistically reads peers' entries off the shared disk).
* :class:`TieredCacheStore` — :class:`~repro.data.store.ObjectStore` facade
  stacking memory over disk over the origin store, with sync ``get`` and
  async-safe ``aget`` (disk I/O is offloaded to the default executor), disk
  hits promoted to memory, and per-GET ``cache_get`` spans recorded through
  :mod:`repro.core.tracing` (``tier=memory|disk|origin``).

Admission policies (applied to the disk tier, where a wasted write costs
I/O *and* evicts something useful):

* ``admit-all``       — cache every miss (the legacy behaviour),
* ``size-threshold``  — only items below a byte threshold (huge objects
  would sweep the whole tier for one future hit),
* ``second-hit``      — admit on the second sighting of a key (Bloom-filter
  based; one-touch scans never pollute the cache),
* ``tinylfu``         — hit-rate-aware frequency admission: a count-min
  sketch with periodic aging estimates each key's recency-weighted access
  frequency (tier hits feed it too), and a miss is admitted only once the
  estimate clears a threshold.

Capacities and the admission policy are runtime-adjustable
(``set_memory_capacity`` / ``set_disk_capacity`` / ``set_admission``), which
is what lets ``repro.core.autotune`` drive them as knobs.
"""
from __future__ import annotations

import asyncio
import hashlib
import os
import threading
import time
from abc import ABC, abstractmethod
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.coord import SharedDiskJournal, host_shard
from repro.core.tracing import CACHE_GET, NULL_TRACER, Tracer


@dataclass(frozen=True)
class CacheTierStats:
    """Unified per-tier counters (a point-in-time snapshot)."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    admitted: int = 0
    rejected: int = 0  # admission-policy / capacity rejections only
    write_failures: int = 0  # I/O errors writing the tier (disk full, EMFILE)
    bytes_used: int = 0
    bytes_admitted: int = 0
    bytes_evicted: int = 0
    shard_foreign: int = 0  # shard-mode puts skipped: key owned by a peer host

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


# ---------------------------------------------------------------------------
# Admission policies
# ---------------------------------------------------------------------------


class AdmissionPolicy(ABC):
    """Decides whether a missed object earns a slot in the tier."""

    name: str = "?"

    @abstractmethod
    def admit(self, key: str, size: int) -> bool: ...


class AdmitAll(AdmissionPolicy):
    name = "admit-all"

    def admit(self, key: str, size: int) -> bool:
        return True


class SizeThresholdAdmission(AdmissionPolicy):
    """Reject items above ``max_item_bytes`` — one giant object can sweep the
    whole tier for a single future hit."""

    name = "size-threshold"

    def __init__(self, max_item_bytes: int) -> None:
        self.max_item_bytes = int(max_item_bytes)

    def admit(self, key: str, size: int) -> bool:
        return size <= self.max_item_bytes


class _BloomFilter:
    """Small thread-safe Bloom filter (blake2b-derived indices)."""

    def __init__(self, num_bits: int = 1 << 17, num_hashes: int = 4) -> None:
        self._nbits = num_bits
        self._k = num_hashes
        self._bits = bytearray(num_bits // 8)
        self._lock = threading.Lock()

    def _indices(self, key: str) -> List[int]:
        h = hashlib.blake2b(key.encode(), digest_size=4 * self._k).digest()
        return [
            int.from_bytes(h[4 * i: 4 * i + 4], "little") % self._nbits
            for i in range(self._k)
        ]

    def test_and_add(self, key: str) -> bool:
        """Return whether ``key`` was (probably) already present; add it."""
        idxs = self._indices(key)
        with self._lock:
            present = all(self._bits[i >> 3] & (1 << (i & 7)) for i in idxs)
            for i in idxs:
                self._bits[i >> 3] |= 1 << (i & 7)
        return present


class SecondHitAdmission(AdmissionPolicy):
    """Admit a key only on its *second* sighting: one-touch scan traffic
    (e.g. a single validation pass) never pollutes the tier."""

    name = "second-hit"

    def __init__(self, num_bits: int = 1 << 17) -> None:
        self._seen = _BloomFilter(num_bits=num_bits)

    def admit(self, key: str, size: int) -> bool:
        return self._seen.test_and_add(key)


# translate table halving every byte — ages the whole sketch in one C pass
_HALVE = bytes(b >> 1 for b in range(256))


class _FreqSketch:
    """Count-min sketch with saturating 4-bit-style counters and periodic
    aging (every counter halves once ``sample_window`` increments have been
    observed) — the TinyLFU frequency estimator.  Aging is what makes the
    estimate *recency-weighted*: a key hot last epoch but cold since decays
    back toward zero instead of staying admitted forever."""

    _MAX = 15

    def __init__(self, num_counters: int = 1 << 16, num_hashes: int = 4,
                 sample_window: int = 0) -> None:
        self._n = num_counters
        self._k = num_hashes
        self._counts = bytearray(num_counters)
        self._window = sample_window or 8 * num_counters
        self._ops = 0
        self._ages = 0
        self._lock = threading.Lock()

    def _indices(self, key: str) -> List[int]:
        h = hashlib.blake2b(key.encode(), digest_size=4 * self._k).digest()
        return [
            int.from_bytes(h[4 * i: 4 * i + 4], "little") % self._n
            for i in range(self._k)
        ]

    def add(self, key: str) -> int:
        """Count one access; return the post-increment min estimate."""
        idxs = self._indices(key)
        with self._lock:
            self._ops += 1
            if self._ops >= self._window:
                self._counts = bytearray(self._counts.translate(_HALVE))
                self._ops = 0
                self._ages += 1
            for i in idxs:
                if self._counts[i] < self._MAX:
                    self._counts[i] += 1
            return min(self._counts[i] for i in idxs)

    def estimate(self, key: str) -> int:
        idxs = self._indices(key)
        with self._lock:
            return min(self._counts[i] for i in idxs)


class TinyLFUAdmission(AdmissionPolicy):
    """Hit-rate-aware TinyLFU-style admission: a miss earns a slot only once
    the key's *recency-weighted* access frequency clears ``threshold``.

    Differences from :class:`SecondHitAdmission` (the Bloom doorkeeper):

    * the frequency sketch **ages** — counters halve every ``sample_window``
      observations, so a key that stopped being accessed has to re-prove
      itself instead of staying admitted on ancient history;
    * tier **hits feed the sketch too** (:meth:`record`, wired by
      ``DiskTierCache.get``), so the estimate tracks the key's real access
      rate, not just how often it missed.
    """

    name = "tinylfu"

    def __init__(self, num_counters: int = 1 << 16, threshold: int = 2,
                 sample_window: int = 0) -> None:
        self._sketch = _FreqSketch(num_counters, sample_window=sample_window)
        self.threshold = threshold

    def admit(self, key: str, size: int) -> bool:
        return self._sketch.add(key) >= self.threshold

    def record(self, key: str) -> None:
        """Count a tier hit (keeps resident keys' frequency warm across
        aging — the 'hit-rate-aware' half of the policy)."""
        self._sketch.add(key)

    def estimate(self, key: str) -> int:
        return self._sketch.estimate(key)


ADMISSION_KINDS = ("admit-all", "size-threshold", "second-hit", "tinylfu")


def make_admission(kind: str, max_item_bytes: int = 1 << 20) -> AdmissionPolicy:
    if kind == "admit-all":
        return AdmitAll()
    if kind == "size-threshold":
        return SizeThresholdAdmission(max_item_bytes)
    if kind == "second-hit":
        return SecondHitAdmission()
    if kind == "tinylfu":
        return TinyLFUAdmission()
    raise ValueError(f"unknown admission policy {kind!r}; known: {ADMISSION_KINDS}")


# ---------------------------------------------------------------------------
# Memory tier
# ---------------------------------------------------------------------------


class _MemShard:
    __slots__ = ("lock", "lru", "used", "hits", "misses", "evictions",
                 "admitted", "rejected", "bytes_admitted", "bytes_evicted")

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.lru: "OrderedDict[str, bytes]" = OrderedDict()
        self.used = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.admitted = 0
        self.rejected = 0
        self.bytes_admitted = 0
        self.bytes_evicted = 0


class MemoryTierCache:
    """Sharded, lock-striped byte-bounded LRU.  Each shard owns 1/N of the
    capacity and its own lock, so the aggregate can never exceed
    ``capacity_bytes`` while concurrent readers rarely contend.

    Striping tradeoff: the largest cacheable item is ``capacity_bytes //
    shards`` — an object bigger than one shard's budget is rejected (counted
    in ``rejected``) rather than allowed to blow the shard's bound.  Size
    jumbo objects for the disk tier, or use fewer shards when single items
    approach the memory budget."""

    def __init__(
        self,
        capacity_bytes: int,
        *,
        shards: int = 1,
        admission: Optional[AdmissionPolicy] = None,
    ) -> None:
        if shards < 1:
            raise ValueError("shards must be >= 1")
        self.capacity = max(int(capacity_bytes), 0)
        self.admission = admission or AdmitAll()
        self._shards = [_MemShard() for _ in range(shards)]

    def _shard(self, key: str) -> _MemShard:
        if len(self._shards) == 1:
            return self._shards[0]
        h = hashlib.blake2b(key.encode(), digest_size=4).digest()
        return self._shards[int.from_bytes(h, "little") % len(self._shards)]

    def _per_shard_capacity(self) -> int:
        return self.capacity // len(self._shards)

    def get(self, key: str) -> Optional[bytes]:
        sh = self._shard(key)
        with sh.lock:
            data = sh.lru.get(key)
            if data is not None:
                sh.lru.move_to_end(key)
                sh.hits += 1
                return data
            sh.misses += 1
            return None

    def put(self, key: str, data: bytes) -> bool:
        size = len(data)
        sh = self._shard(key)
        if not self.admission.admit(key, size):
            with sh.lock:
                sh.rejected += 1
            return False
        with sh.lock:
            # capacity is read under the shard lock: a concurrent
            # set_capacity shrink must not leave this shard sized (and
            # evicted) against the stale larger budget
            cap = self._per_shard_capacity()
            if size > cap:
                sh.rejected += 1
                return False
            if key in sh.lru:
                sh.lru.move_to_end(key)
                return True
            sh.lru[key] = data
            sh.used += size
            sh.admitted += 1
            sh.bytes_admitted += size
            self._evict_shard_locked(sh, cap)
        return True

    def _evict_shard_locked(self, sh: _MemShard, cap: int) -> None:
        while sh.used > cap and sh.lru:
            _, victim = sh.lru.popitem(last=False)
            sh.used -= len(victim)
            sh.evictions += 1
            sh.bytes_evicted += len(victim)

    def set_capacity(self, capacity_bytes: int) -> int:
        self.capacity = max(int(capacity_bytes), 0)
        cap = self._per_shard_capacity()
        for sh in self._shards:
            with sh.lock:
                self._evict_shard_locked(sh, cap)
        return self.capacity

    @property
    def used_bytes(self) -> int:
        return sum(sh.used for sh in self._shards)

    def stats(self) -> CacheTierStats:
        agg = dict(hits=0, misses=0, evictions=0, admitted=0, rejected=0,
                   bytes_used=0, bytes_admitted=0, bytes_evicted=0)
        for sh in self._shards:
            with sh.lock:
                agg["hits"] += sh.hits
                agg["misses"] += sh.misses
                agg["evictions"] += sh.evictions
                agg["admitted"] += sh.admitted
                agg["rejected"] += sh.rejected
                agg["bytes_used"] += sh.used
                agg["bytes_admitted"] += sh.bytes_admitted
                agg["bytes_evicted"] += sh.bytes_evicted
        return CacheTierStats(**agg)


# ---------------------------------------------------------------------------
# Disk tier
# ---------------------------------------------------------------------------


class _DiskEntry:
    __slots__ = ("size", "final", "read_failures")

    def __init__(self, size: int, final: bool) -> None:
        self.size = size
        self.final = final
        self.read_failures = 0  # consecutive non-ENOENT read errors


class DiskTierCache:
    """Byte-bounded on-disk LRU with atomic writes and pluggable admission.

    Capacity accounting is *reservation-based*: a writer reserves its bytes in
    the index (evicting LRU victims as needed) before touching the disk, so
    the sum of finalized cache files never exceeds ``capacity_bytes`` even
    under parallel writers.  ``capacity_bytes=0`` means unbounded (the legacy
    ``DiskCacheStore`` behaviour).  Same-key writers serialize on a striped
    lock; distinct keys proceed in parallel.

    Multi-host modes (both off by default — single-host behaviour is
    unchanged):

    * ``journal`` — pass a :class:`~repro.core.coord.SharedDiskJournal`: the
      in-process index is replaced by the cross-process byte journal, so N
      writer processes on one shared directory still never overshoot
      ``capacity_bytes`` (the journal's capacity is authoritative).
    * ``shard=(host_id, n_hosts)`` — the keyspace is partitioned with
      :func:`~repro.core.coord.host_shard`; this instance admits and accounts
      only its own shard (``capacity_bytes`` is per-host) while GETs for
      peer-owned keys read the shared directory opportunistically.  File
      names carry the owning shard as a prefix so re-indexing on init never
      adopts a peer's bytes into this host's budget.
    """

    def __init__(
        self,
        cache_dir: str,
        capacity_bytes: int = 0,
        admission: Optional[AdmissionPolicy] = None,
        *,
        write_stripes: int = 16,
        journal: Optional[SharedDiskJournal] = None,
        shard: Optional[Tuple[int, int]] = None,
        tmp_grace_s: float = 120.0,
    ) -> None:
        if journal is not None and shard is not None:
            raise ValueError("journal and shard coordination are exclusive")
        if shard is not None and not 0 <= shard[0] < shard[1]:
            # host_shard() only ever returns 0..n_hosts-1: an out-of-range
            # host id (e.g. 1-based) would silently own NO keys — every put
            # skipped, no disk tier at all, and no error to say so
            raise ValueError(
                f"shard host_id {shard[0]} out of range for {shard[1]} hosts "
                "(host ids are 0-based)"
            )
        self.dir = cache_dir
        self.capacity = max(int(capacity_bytes), 0)
        self.admission = admission or AdmitAll()
        self.journal = journal
        self.shard = shard
        # shard mode: the keyspace slots this instance currently owns.  The
        # static default is exactly {host_id}; elastic membership handoff
        # rewrites it live through reshard().
        self._owned = frozenset({shard[0]}) if shard is not None else frozenset()
        self._owned_prefixes: Tuple[str, ...] = tuple(
            self._shard_prefix(s) for s in sorted(self._owned)
        )
        self.tmp_grace_s = tmp_grace_s
        os.makedirs(cache_dir, exist_ok=True)
        self._index: "OrderedDict[str, _DiskEntry]" = OrderedDict()
        self._used = 0
        self._lock = threading.Lock()  # index + counters
        self._stripes = [threading.Lock() for _ in range(write_stripes)]
        self.orphans_removed = 0
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._admitted = 0
        self._rejected = 0
        self._write_failures = 0
        self._bytes_admitted = 0
        self._bytes_evicted = 0
        self._shard_foreign = 0
        self._recover()

    # -- init / recovery -----------------------------------------------------
    def _recover(self) -> None:
        """Purge orphaned tmp files from crashed writers; re-index surviving
        entries (oldest mtime first, so recovered LRU order is sensible).

        Multi-process tolerance: a *fresh* tmp file (mtime within
        ``tmp_grace_s``) belongs to a live writer in another process — on a
        shared directory, purging it would yank an in-flight write out from
        under a peer — so only stale tmps are treated as crash orphans.  In
        shard mode only files carrying this host's shard prefix are adopted
        (a peer's entries are its budget, not ours); in journal mode the
        directory is reconciled against the shared journal instead of
        rebuilding a private index."""
        now = time.time()
        found = []
        for name in os.listdir(self.dir):
            if name.startswith("."):  # coordination state (.coord), dotfiles
                continue
            path = os.path.join(self.dir, name)
            if ".tmp" in name:
                try:
                    if now - os.stat(path).st_mtime >= self.tmp_grace_s:
                        os.remove(path)
                        self.orphans_removed += 1
                except OSError:
                    pass
                continue
            if self.journal is not None:
                continue  # the journal re-lists under its own lock below
            if self.shard is not None and not self._owns(name):
                continue  # a peer host's entry (or pre-shard debris): not ours
            try:
                st = os.stat(path)
            except OSError:
                continue
            found.append((st.st_mtime, name, st.st_size))
        if self.journal is not None:
            # listing happens inside the journal lock — a pre-lock listing
            # would race live peers and leak their just-finalized bytes
            self.journal.reconcile(capacity_bytes=self.capacity)
            return
        for _, name, size in sorted(found):
            self._index[name] = _DiskEntry(size, True)
            self._used += size
        with self._lock:  # a shrunk capacity still bounds a reload
            paths = self._pop_victims_locked()
        self._unlink(paths)

    # -- key mapping ---------------------------------------------------------
    def _shard_prefix(self, owner: Optional[int] = None) -> str:
        if owner is None:
            owner = self.shard[0]
        return f"s{owner:03d}-"

    def _fname(self, key: str) -> str:
        digest = hashlib.sha1(key.encode()).hexdigest()
        if self.shard is not None:
            return self._shard_prefix(host_shard(key, self.shard[1])) + digest
        return digest

    def _owns(self, fname: str) -> bool:
        return self.shard is None or fname.startswith(self._owned_prefixes)

    def _path(self, fname: str) -> str:
        return os.path.join(self.dir, fname)

    def _stripe(self, fname: str) -> threading.Lock:
        # the trailing 8 chars are always hex digest (shard mode prefixes)
        return self._stripes[int(fname[-8:], 16) % len(self._stripes)]

    # -- eviction ------------------------------------------------------------
    def _pop_victims_locked(self, need: int = 0) -> List[str]:
        """Pop LRU *finalized* entries from the index until ``need`` more
        bytes fit; return their paths for the caller to unlink.  Provisional
        (mid-write) entries are skipped: their file does not exist yet and
        popping them would corrupt the writer's accounting."""
        paths: List[str] = []
        while self.capacity and self._used + need > self.capacity:
            victim = next((f for f, e in self._index.items() if e.final), None)
            if victim is None:
                break
            entry = self._index.pop(victim)
            self._used -= entry.size
            self._evictions += 1
            self._bytes_evicted += entry.size
            paths.append(self._path(victim))
        return paths

    @staticmethod
    def _unlink(paths: List[str]) -> None:
        for p in paths:
            try:
                os.remove(p)
            except OSError:
                pass

    def _evict_locked(self, need: int = 0) -> None:
        """One-item-sized eviction for the get/put hot paths: the unlink
        stays under the lock so the on-disk bytes never exceed the accounted
        bytes (the bound tests scan the directory concurrently).  Bulk
        sweeps (capacity shrink) go through set_capacity, which unlinks
        OUTSIDE the lock."""
        self._unlink(self._pop_victims_locked(need))

    # -- get / put -----------------------------------------------------------
    def _get_journal(self, fname: str) -> Optional[bytes]:
        """Journal-mode GET: the file system is read directly; the shared
        journal only learns about recency (LRU touch) and externally vanished
        entries.  A peer evicting between our open and the touch is benign —
        we still serve the bytes our fd pinned, and touch() on a gone entry
        is a no-op."""
        try:
            with open(self._path(fname), "rb") as f:
                data = f.read()
        except FileNotFoundError:
            self.journal.repair_missing(fname)
            with self._lock:
                self._misses += 1
            return None
        except OSError:
            with self._lock:
                self._misses += 1
            return None
        self.journal.touch(fname)
        with self._lock:
            self._hits += 1
        return data

    def _get_foreign(self, fname: str) -> Optional[bytes]:
        """Shard-mode GET for a key owned by a peer host: opportunistic read
        of the shared directory, no accounting (the bytes live in the owner's
        budget and only the owner maintains LRU order)."""
        try:
            with open(self._path(fname), "rb") as f:
                data = f.read()
        except OSError:
            with self._lock:
                self._misses += 1
            return None
        with self._lock:
            self._hits += 1
        return data

    def _note_hit(self, key: str) -> None:
        """Feed hit-rate-aware admission policies (TinyLFU) the hit stream;
        duck-typed so the stateless policies cost nothing."""
        rec = getattr(self.admission, "record", None)
        if rec is not None:
            rec(key)

    def get(self, key: str) -> Optional[bytes]:
        fname = self._fname(key)
        if self.journal is not None:
            data = self._get_journal(fname)
            if data is not None:
                self._note_hit(key)
            return data
        if not self._owns(fname):
            # a peer host's key: its owner does the admission accounting
            return self._get_foreign(fname)
        try:
            with open(self._path(fname), "rb") as f:
                data = f.read()
        except FileNotFoundError:
            with self._lock:
                entry = self._index.get(fname)
                if entry is not None and entry.final:
                    # vanished mid-read (external delete / crash leftover):
                    # repair the byte accounting instead of leaking it
                    del self._index[fname]
                    self._used -= entry.size
                self._misses += 1
            return None
        except OSError:
            # transient failure (EMFILE, EACCES, mid-read error): the file
            # may well still exist — count the miss but keep the accounting,
            # or the still-present bytes would become untracked and push
            # real disk usage over capacity.  A PERSISTENTLY unreadable
            # entry must not stay pinned forever though (put()'s dedup
            # fast-path refreshes it to MRU on every origin refill), so
            # after a few consecutive failures drop it and unlink.
            with self._lock:
                self._misses += 1
                entry = self._index.get(fname)
                if entry is not None and entry.final:
                    entry.read_failures += 1
                    if entry.read_failures >= 3:
                        del self._index[fname]
                        self._used -= entry.size
                        try:
                            os.remove(self._path(fname))
                        except OSError:
                            pass
            return None
        with self._lock:
            entry = self._index.get(fname)
            if entry is not None:
                entry.read_failures = 0
                self._index.move_to_end(fname)
            # not indexed: either a concurrent eviction unlinked the file
            # while our fd kept the read alive, or an external process
            # dropped a file in mid-run.  Either way the bytes must NOT be
            # (re-)indexed — adopting a just-evicted name would create a
            # phantom entry whose file is gone, corrupting the accounting
            # and short-circuiting the next put().  Serve the data as a hit
            # and leave the index alone (externally placed files are only
            # adopted by _recover at init).
            self._hits += 1
        self._note_hit(key)
        return data

    def _put_journal(self, fname: str, data: bytes) -> bool:
        """Journal-mode PUT: reserve in the shared journal (which evicts
        victims — possibly a peer's — under its cross-process lock), then
        write tmp + rename, then finalize.  A finalize that comes back False
        means our reservation expired mid-write (writer slower than the
        journal's reserve TTL): the renamed file is no longer accounted for,
        so it must be unlinked rather than become untracked bytes."""
        size = len(data)
        with self._stripe(fname):
            res = self.journal.reserve(fname, size)
            if res.dedup:
                return True
            if not res.ok:
                with self._lock:
                    self._rejected += 1
                return False
            with self._lock:
                self._evictions += res.evicted
                self._bytes_evicted += res.evicted_bytes
            tmp = self._path(fname) + f".tmp{os.getpid()}-{threading.get_ident()}"
            try:
                with open(tmp, "wb") as f:
                    f.write(data)
                os.replace(tmp, self._path(fname))
            except OSError:
                self.journal.abort(fname)
                with self._lock:
                    self._write_failures += 1
                try:
                    os.remove(tmp)
                except OSError:
                    pass
                return False
            if not self.journal.finalize(fname):
                self._unlink([self._path(fname)])
                with self._lock:
                    self._write_failures += 1
                return False
            with self._lock:
                self._admitted += 1
                self._bytes_admitted += size
        return True

    def put(self, key: str, data: bytes) -> bool:
        size = len(data)
        fname = self._fname(key)
        if not self._owns(fname):
            with self._lock:
                self._shard_foreign += 1
            return False
        if (self.capacity and size > self.capacity) or not self.admission.admit(key, size):
            with self._lock:
                self._rejected += 1
            return False
        if self.journal is not None:
            return self._put_journal(fname, data)
        with self._stripe(fname):
            with self._lock:
                if fname in self._index:
                    self._index.move_to_end(fname)
                    return True
                if self.capacity:
                    self._evict_locked(need=size)
                    if self._used + size > self.capacity:
                        # only mid-write reservations left to evict
                        self._rejected += 1
                        return False
                self._index[fname] = _DiskEntry(size, False)
                self._used += size
            tmp = self._path(fname) + f".tmp{threading.get_ident()}"
            try:
                with open(tmp, "wb") as f:
                    f.write(data)
                os.replace(tmp, self._path(fname))
            except OSError:
                with self._lock:
                    entry = self._index.pop(fname, None)
                    if entry is not None:
                        self._used -= entry.size
                    self._write_failures += 1  # I/O failure, not a rejection
                try:
                    os.remove(tmp)
                except OSError:
                    pass
                return False
            with self._lock:
                entry = self._index.get(fname)
                if entry is not None:
                    entry.final = True
                self._admitted += 1
                self._bytes_admitted += size
        return True

    # -- control / observability ---------------------------------------------
    def set_capacity(self, capacity_bytes: int) -> int:
        """A shrink can evict thousands of entries; victims are popped under
        the lock but unlinked after releasing it, so concurrent get/put
        traffic is not stalled behind the whole deletion sweep.  In journal
        mode the shared journal's capacity is authoritative and the change is
        visible to every process sharing the directory."""
        if self.journal is not None:
            self.capacity = self.journal.set_capacity(capacity_bytes)
            return self.capacity
        with self._lock:
            self.capacity = max(int(capacity_bytes), 0)
            paths = self._pop_victims_locked()
        self._unlink(paths)
        return self.capacity

    def set_admission(self, policy: AdmissionPolicy) -> None:
        self.admission = policy

    def reshard(self, owned_slots) -> Dict[str, int]:
        """Shard-mode elastic handoff: replace the set of keyspace slots
        this host owns (computed fleet-wide from the membership view with
        :func:`repro.core.coord.slot_owners`) without restarting.

        * **released** slots: their entries leave *this index only* — the
          files stay on disk for the slot's new owner to adopt (unlinking
          them would throw away a warm cache the fleet still wants), and
          this host's budget is freed immediately;
        * **gained** slots: their on-disk files are adopted at the LRU cold
          end in mtime order (the same rule ``_recover`` uses), then the
          index is evicted down to ``capacity_bytes`` — so the per-host
          byte bound holds through the handoff at every instant.

        Provisional (mid-write) entries of released slots are kept until
        their writer finishes; the next reshard or eviction retires them.
        Returns ``{"dropped": n, "adopted": n}``."""
        if self.shard is None:
            raise ValueError("reshard() requires shard mode")
        owned = frozenset(int(s) for s in owned_slots)
        for s in owned:
            if not 0 <= s < self.shard[1]:
                raise ValueError(
                    f"slot {s} out of range for {self.shard[1]} shard slots"
                )
        dropped = adopted = 0
        with self._lock:
            gained = owned - self._owned
            self._owned = owned
            self._owned_prefixes = tuple(
                self._shard_prefix(s) for s in sorted(owned)
            )
            for fname in [f for f in self._index if not self._owns(f)]:
                entry = self._index[fname]
                if not entry.final:
                    continue  # a live writer still owns this reservation
                del self._index[fname]
                self._used -= entry.size
                dropped += 1
            if gained:
                prefixes = tuple(self._shard_prefix(s) for s in sorted(gained))
                found = []
                for name in os.listdir(self.dir):
                    if name.startswith(".") or ".tmp" in name:
                        continue
                    if not name.startswith(prefixes) or name in self._index:
                        continue
                    try:
                        st = os.stat(self._path(name))
                    except OSError:
                        continue
                    found.append((st.st_mtime, name, st.st_size))
                # newest-first insertion at the front leaves the oldest
                # adoptee coldest, matching _recover's mtime LRU order
                for _, name, size in sorted(found, reverse=True):
                    self._index[name] = _DiskEntry(size, True)
                    self._index.move_to_end(name, last=False)
                    self._used += size
                    adopted += 1
            paths = self._pop_victims_locked()
        self._unlink(paths)
        return {"dropped": dropped, "adopted": adopted}

    @property
    def used_bytes(self) -> int:
        if self.journal is not None:
            return self.journal.used_bytes()
        with self._lock:
            return self._used

    def stats(self) -> CacheTierStats:
        """Per-process counters; ``bytes_used`` is the tier-wide figure in
        journal mode (each process's hit/miss/eviction counts describe its
        own operations, which is what stays meaningful under contention)."""
        bytes_used = (
            self.journal.used_bytes() if self.journal is not None else None
        )
        with self._lock:
            return CacheTierStats(
                hits=self._hits,
                misses=self._misses,
                evictions=self._evictions,
                admitted=self._admitted,
                rejected=self._rejected,
                write_failures=self._write_failures,
                bytes_used=self._used if bytes_used is None else bytes_used,
                bytes_admitted=self._bytes_admitted,
                bytes_evicted=self._bytes_evicted,
                shard_foreign=self._shard_foreign,
            )


# ---------------------------------------------------------------------------
# Tiered facade
# ---------------------------------------------------------------------------


class TieredCacheStore:
    """Memory LRU over a bounded disk tier over the origin store.

    Implements the :class:`repro.data.store.ObjectStore` protocol (registered
    as a virtual subclass by ``repro.data.store`` to avoid a circular import).
    Disk hits are promoted to memory; origin fetches are written through both
    tiers.  Each GET records a ``cache_get`` tracing span tagged with the
    serving tier, so hit/miss/byte composition is visible in the same
    Perfetto timeline / ``window_summary`` pipeline as the loader stages.
    """

    ADMISSION_KINDS = ADMISSION_KINDS

    def __init__(
        self,
        base,
        *,
        memory: Optional[MemoryTierCache] = None,
        disk: Optional[DiskTierCache] = None,
        tracer: Tracer = NULL_TRACER,
        admission_max_item_bytes: int = 1 << 20,
    ) -> None:
        if memory is None and disk is None:
            raise ValueError("TieredCacheStore needs at least one tier")
        self.base = base
        self.memory = memory
        self.disk = disk
        self.tracer = tracer
        self.admission_max_item_bytes = admission_max_item_bytes
        # policies are memoized per index so stateful ones (second-hit's
        # Bloom filter) survive autotune probe/revert toggles instead of
        # being reset to empty on every knob move
        self._admission_by_index: dict = {}
        if disk is not None:
            self._admission_by_index[self.admission_index()] = disk.admission

    # -- trace helper --------------------------------------------------------
    def _trace(self, t0: float, tier: str, nbytes: int) -> None:
        self.tracer.record(CACHE_GET, t0, time.monotonic(), tier=tier, nbytes=nbytes)

    # -- ObjectStore surface -------------------------------------------------
    def get(self, key: str) -> bytes:
        t0 = time.monotonic()
        if self.memory is not None:
            data = self.memory.get(key)
            if data is not None:
                self._trace(t0, "memory", len(data))
                return data
        if self.disk is not None:
            data = self.disk.get(key)
            if data is not None:
                if self.memory is not None:
                    self.memory.put(key, data)
                self._trace(t0, "disk", len(data))
                return data
        data = self.base.get(key)
        if self.disk is not None:
            self.disk.put(key, data)
        if self.memory is not None:
            self.memory.put(key, data)
        self._trace(t0, "origin", len(data))
        return data

    def lookup(self, key: str) -> Optional[Tuple[bytes, str]]:
        """Cache-tier-only probe: ``(data, tier)`` on a memory/disk hit
        (promoting disk hits exactly like :meth:`get`), ``None`` on a miss —
        never touches the origin.  The serving read path uses this to decide
        which requests enter single-flight coalescing / tenant metering:
        only true misses pay for a backend fetch."""
        t0 = time.monotonic()
        if self.memory is not None:
            data = self.memory.get(key)
            if data is not None:
                self._trace(t0, "memory", len(data))
                return data, "memory"
        if self.disk is not None:
            data = self.disk.get(key)
            if data is not None:
                if self.memory is not None:
                    self.memory.put(key, data)
                self._trace(t0, "disk", len(data))
                return data, "disk"
        return None

    async def aget(self, key: str) -> bytes:
        """Async-safe GET: memory is O(1) inline, disk I/O runs on the
        default executor, the origin uses its own ``aget``."""
        t0 = time.monotonic()
        if self.memory is not None:
            data = self.memory.get(key)
            if data is not None:
                self._trace(t0, "memory", len(data))
                return data
        loop = asyncio.get_running_loop()
        if self.disk is not None:
            data = await loop.run_in_executor(None, self.disk.get, key)
            if data is not None:
                if self.memory is not None:
                    self.memory.put(key, data)
                self._trace(t0, "disk", len(data))
                return data
        data = await self.base.aget(key)
        if self.disk is not None:
            await loop.run_in_executor(None, self.disk.put, key, data)
        if self.memory is not None:
            self.memory.put(key, data)
        self._trace(t0, "origin", len(data))
        return data

    def put(self, key: str, data: bytes) -> None:
        self.base.put(key, data)

    def list_keys(self, prefix: str = "") -> List[str]:
        return self.base.list_keys(prefix)

    def size(self, key: str) -> int:
        return self.base.size(key)

    def close(self) -> None:
        self.base.close()

    # -- unified stats -------------------------------------------------------
    def cache_stats(self) -> dict:
        """Snapshot of every tier (named ``cache_stats`` so the autotuner's
        store-stack walk still finds ``SimulatedS3Store.stats`` underneath)."""
        out = {}
        if self.memory is not None:
            out["memory"] = self.memory.stats()
        if self.disk is not None:
            out["disk"] = self.disk.stats()
        return out

    @property
    def hit_rate(self) -> float:
        """Fraction of external GETs served by *any* tier."""
        outer = self.memory if self.memory is not None else self.disk
        total = outer.stats().lookups
        if not total:
            return 0.0
        inner = self.disk if self.disk is not None else self.memory
        origin_fetches = inner.stats().misses
        return (total - origin_fetches) / total

    # -- autotune knob surfaces ----------------------------------------------
    def set_memory_capacity(self, capacity_bytes: int) -> int:
        if self.memory is None:
            return 0
        return self.memory.set_capacity(capacity_bytes)

    def set_disk_capacity(self, capacity_bytes: int) -> int:
        if self.disk is None:
            return 0
        return self.disk.set_capacity(capacity_bytes)

    def admission_index(self) -> int:
        if self.disk is None:
            return 0
        try:
            return ADMISSION_KINDS.index(self.disk.admission.name)
        except ValueError:
            return 0

    def set_admission(self, index: int) -> int:
        if self.disk is None:
            return 0
        index = max(0, min(int(index), len(ADMISSION_KINDS) - 1))
        if index not in self._admission_by_index:
            self._admission_by_index[index] = make_admission(
                ADMISSION_KINDS[index], self.admission_max_item_bytes
            )
        self.disk.set_admission(self._admission_by_index[index])
        return index


# ---------------------------------------------------------------------------
# Legacy shims (public names re-exported by repro.data.store)
# ---------------------------------------------------------------------------


class CachedStore(TieredCacheStore):
    """Single-tier in-memory LRU — the original ``CachedStore`` surface
    (exact global LRU via one shard; ``hits``/``misses``/``hit_rate``)."""

    def __init__(self, base, capacity_bytes: int) -> None:
        super().__init__(base, memory=MemoryTierCache(capacity_bytes, shards=1))

    @property
    def capacity(self) -> int:
        return self.memory.capacity

    @property
    def hits(self) -> int:
        return self.memory.stats().hits

    @property
    def misses(self) -> int:
        return self.memory.stats().misses

    @property
    def _used(self) -> int:
        return self.memory.used_bytes


class DiskCacheStore(TieredCacheStore):
    """Single-tier on-disk cache — the original ``DiskCacheStore`` surface,
    now with optional byte bound + admission (0 = unbounded, as before)."""

    def __init__(
        self,
        base,
        cache_dir: str,
        capacity_bytes: int = 0,
        admission: Optional[AdmissionPolicy] = None,
    ) -> None:
        super().__init__(
            base, disk=DiskTierCache(cache_dir, capacity_bytes, admission)
        )

    @property
    def hits(self) -> int:
        return self.disk.stats().hits

    @property
    def misses(self) -> int:
        return self.disk.stats().misses
