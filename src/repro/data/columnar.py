"""Columnar shard tier: per-field chunks, chunk statistics, predicate pushdown.

Every earlier tier (cache, pipeline, shm, serve) moves *whole* items even
when a transform needs one field or a filtered epoch needs a quarter of the
rows.  On high-latency storage the dominant cost is bytes moved per sample
(the paper's central measurement), so this module stores shards column-wise
and lets the read path skip bytes instead of discarding them:

* **Format** — a shard is ``MAGIC | chunk payloads | footer | trailer``.
  Each chunk holds one *field* over a contiguous row range, with per-row
  offsets and per-chunk statistics (min/max, value histogram, payload
  lengths).  The JSON footer indexes every chunk; the fixed trailer
  (``footer_len | crc32 | RCOLFTR1``) makes truncated writes detectable:
  a crash mid-write can never yield a readable-but-wrong shard.
* **Projection** — :class:`ColumnarImageDataset` fetches only the fields its
  transform declares; small scalar columns (label, shape, lengths) live in
  the footer, so predicate evaluation never touches payload chunks.
* **Pushdown** — a callable-free predicate DSL (``("label", "in", (...))``,
  ``("length", "<", n)``) is evaluated against footer metadata and chunk
  statistics *before* any payload GET: pruned chunks are never requested
  from the store, which is what makes a 25%-selectivity epoch cost ~25% of
  the bytes instead of 100%.
* **Cache granularity** — :class:`ColumnarStore` stores each chunk as its
  own object key, so the tiered cache and the simulated S3 account (and
  cache) field-chunks, not whole items.

The predicate DSL is deliberately tuple-only (no callables) so it is
picklable, serializable into configs/checkpoints, and evaluable both
row-wise (exact) and chunk-wise (conservative, via statistics).
"""
from __future__ import annotations

import json
import struct
import threading
import time
import zlib
from collections import OrderedDict
from typing import Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.tracing import NULL_TRACER, Tracer
from repro.data import codec
from repro.data.dataset import ImageDataset
from repro.data.store import ObjectStore

MAGIC = b"RCOL1\n"
_FOOTER_MAGIC = b"RCOLFTR1"
_TRAILER = struct.Struct("<QI")  # footer_len, crc32(footer_json)
_TRAILER_LEN = _TRAILER.size + len(_FOOTER_MAGIC)  # 20 bytes
_HIST_MAX = 256  # keep a value histogram only while a chunk stays this diverse
_RIMG_HEADER = 21  # magic(4) + struct "<IIIIB"

OPS = ("==", "!=", "<", "<=", ">", ">=", "in", "not_in")

Clause = Tuple[str, str, object]


class ColumnarError(ValueError):
    """Malformed columnar shard or predicate."""


class TruncatedShard(ColumnarError):
    """Shard blob fails integrity checks (crash-truncated or corrupt)."""


# ---------------------------------------------------------------------------
# predicate DSL
# ---------------------------------------------------------------------------

def validate_clauses(clauses: Sequence[Clause]) -> Tuple[Clause, ...]:
    """Normalize and validate DSL clauses (tuple-only, no callables)."""
    out: List[Clause] = []
    for cl in clauses:
        if not (isinstance(cl, (tuple, list)) and len(cl) == 3):
            raise ColumnarError(f"clause must be (field, op, value), got {cl!r}")
        field, op, value = cl
        if not isinstance(field, str) or not field:
            raise ColumnarError(f"clause field must be a string, got {field!r}")
        if op not in OPS:
            raise ColumnarError(f"clause op must be one of {OPS}, got {op!r}")
        if op in ("in", "not_in"):
            if isinstance(value, (str, bytes)) or not isinstance(value, Iterable):
                raise ColumnarError(f"{op!r} needs an iterable of values, got {value!r}")
            value = tuple(int(v) for v in value)
        else:
            value = int(value)
        out.append((field, op, value))
    return tuple(out)


def predicate_mask(
    columns: Dict[str, np.ndarray], clauses: Sequence[Clause]
) -> np.ndarray:
    """Vectorized row mask: AND of all clauses over metadata columns."""
    clauses = validate_clauses(clauses)
    n = len(next(iter(columns.values()))) if columns else 0
    mask = np.ones(n, dtype=bool)
    for field, op, value in clauses:
        if field not in columns:
            raise ColumnarError(f"unknown predicate field {field!r}; "
                                f"have {sorted(columns)}")
        col = np.asarray(columns[field])
        if op == "in":
            m = np.isin(col, np.asarray(value, dtype=col.dtype))
        elif op == "not_in":
            m = ~np.isin(col, np.asarray(value, dtype=col.dtype))
        elif op == "==":
            m = col == value
        elif op == "!=":
            m = col != value
        elif op == "<":
            m = col < value
        elif op == "<=":
            m = col <= value
        elif op == ">":
            m = col > value
        else:  # ">="
            m = col >= value
        mask &= m
    return mask


def row_matches(meta: Dict[str, Sequence[int]], row: int,
                clauses: Sequence[Clause]) -> bool:
    """Exact scalar evaluation of the clause list for one row."""
    cols = {f: np.asarray(meta[f]) for f, _, _ in validate_clauses(clauses)}
    return bool(predicate_mask(cols, clauses)[row]) if cols else True


def chunk_matches(stats: Dict[str, Dict], clauses: Sequence[Clause]) -> bool:
    """Conservative chunk test: False only when NO row in the chunk can
    satisfy the clause list — the soundness contract pushdown relies on
    (a pruned chunk provably contains no matching row)."""
    for field, op, value in validate_clauses(clauses):
        s = stats.get(field)
        if s is None:
            continue  # no statistics for this column: cannot prune
        lo, hi, hist = s.get("min"), s.get("max"), s.get("hist")
        if op == "in":
            if hist is not None:
                if not any(str(v) in hist for v in value):
                    return False
            elif not any(lo <= v <= hi for v in value):
                return False
        elif op == "not_in":
            if hist is not None:
                if all(int(k) in value for k in hist):
                    return False
            elif lo == hi and lo in value:
                return False
        elif op == "==":
            if hist is not None:
                if str(value) not in hist:
                    return False
            elif not (lo <= value <= hi):
                return False
        elif op == "!=":
            if lo == hi == value:
                return False
        elif op == "<":
            if not (lo < value):
                return False
        elif op == "<=":
            if not (lo <= value):
                return False
        elif op == ">":
            if not (hi > value):
                return False
        else:  # ">="
            if not (hi >= value):
                return False
    return True


def clause_fields(clauses: Sequence[Clause]) -> Tuple[str, ...]:
    return tuple(dict.fromkeys(f for f, _, _ in validate_clauses(clauses)))


# ---------------------------------------------------------------------------
# shard codec (single-blob form; the store explodes it into per-chunk keys)
# ---------------------------------------------------------------------------

def _column_stats(values: Sequence[int]) -> Dict:
    vals = [int(v) for v in values]
    stats: Dict = {"min": min(vals), "max": max(vals)}
    if len(set(vals)) <= _HIST_MAX:
        hist: Dict[str, int] = {}
        for v in vals:
            hist[str(v)] = hist.get(str(v), 0) + 1
        stats["hist"] = hist
    return stats


def _build_chunks(
    rows: Sequence[Dict[str, bytes]],
    meta: Dict[str, Sequence[int]],
    fields: Sequence[str],
    rows_per_chunk: int,
) -> Tuple[List[bytes], List[Dict]]:
    """Split rows into per-field chunk payloads + footer index entries."""
    payloads: List[bytes] = []
    index: List[Dict] = []
    n = len(rows)
    for field in fields:
        for lo in range(0, n, rows_per_chunk):
            hi = min(lo + rows_per_chunk, n)
            blobs = [bytes(rows[r][field]) for r in range(lo, hi)]
            row_offsets = [0]
            for b in blobs:
                row_offsets.append(row_offsets[-1] + len(b))
            payload = b"".join(blobs)
            stats = {col: _column_stats(vals[lo:hi]) for col, vals in meta.items()}
            stats["length"] = _column_stats([len(b) for b in blobs])
            payloads.append(payload)
            index.append({
                "field": field, "row_lo": lo, "row_hi": hi,
                "size": len(payload), "row_offsets": row_offsets,
                "stats": stats,
            })
    return payloads, index


def _footer_bytes(footer: Dict) -> bytes:
    fjson = json.dumps(footer, separators=(",", ":"), sort_keys=True).encode()
    return fjson + _TRAILER.pack(len(fjson), zlib.crc32(fjson)) + _FOOTER_MAGIC


def read_footer(data: bytes) -> Dict:
    """Parse + integrity-check the footer at the end of ``data``.

    Raises :class:`TruncatedShard` on any truncation or corruption — the
    trailer magic, the footer length, and the footer crc32 must all agree,
    so a crash-truncated write is detected rather than misread.
    """
    if len(data) < _TRAILER_LEN:
        raise TruncatedShard("blob shorter than the footer trailer")
    if data[-len(_FOOTER_MAGIC):] != _FOOTER_MAGIC:
        raise TruncatedShard("footer magic missing (truncated write?)")
    flen, crc = _TRAILER.unpack(data[-_TRAILER_LEN:-len(_FOOTER_MAGIC)])
    if flen + _TRAILER_LEN > len(data):
        raise TruncatedShard("footer length exceeds blob size")
    fjson = data[len(data) - _TRAILER_LEN - flen : len(data) - _TRAILER_LEN]
    if zlib.crc32(fjson) != crc:
        raise TruncatedShard("footer checksum mismatch")
    try:
        footer = json.loads(fjson)
    except ValueError as e:  # pragma: no cover - crc makes this unreachable
        raise TruncatedShard(f"footer is not valid JSON: {e}") from e
    if footer.get("version") != 1:
        raise ColumnarError(f"unsupported columnar version {footer.get('version')!r}")
    return footer


def pack_shard(
    rows: Sequence[Dict[str, bytes]],
    meta: Optional[Dict[str, Sequence[int]]] = None,
    *,
    rows_per_chunk: int = 8,
) -> bytes:
    """Pack rows (dict field -> ragged bytes) + scalar metadata columns into
    one self-describing shard blob."""
    if not rows:
        raise ColumnarError("cannot pack an empty shard")
    if rows_per_chunk < 1:
        raise ColumnarError("rows_per_chunk must be >= 1")
    fields = sorted(rows[0])
    if not fields:
        raise ColumnarError("rows must have at least one field")
    for r, row in enumerate(rows):
        if sorted(row) != fields:
            raise ColumnarError(f"row {r} fields {sorted(row)} != {fields}")
    meta = {k: [int(v) for v in vals] for k, vals in (meta or {}).items()}
    for col, vals in meta.items():
        if len(vals) != len(rows):
            raise ColumnarError(f"meta column {col!r} has {len(vals)} values "
                                f"for {len(rows)} rows")
    payloads, index = _build_chunks(rows, meta, fields, rows_per_chunk)
    offset = len(MAGIC)
    for payload, entry in zip(payloads, index):
        entry["offset"] = offset
        offset += len(payload)
    footer = {
        "version": 1, "num_rows": len(rows), "fields": fields,
        "rows_per_chunk": rows_per_chunk, "meta": meta, "chunks": index,
    }
    return MAGIC + b"".join(payloads) + _footer_bytes(footer)


def unpack_shard(blob: bytes) -> Tuple[List[Dict[str, bytes]], Dict[str, List[int]]]:
    """Inverse of :func:`pack_shard` (round-trip; used by tests/converter)."""
    if blob[: len(MAGIC)] != MAGIC:
        raise TruncatedShard("not a columnar shard (bad magic)")
    footer = read_footer(blob)
    body_end = None  # chunks must fit before the footer
    rows: List[Dict[str, bytes]] = [dict() for _ in range(footer["num_rows"])]
    for ch in footer["chunks"]:
        lo, hi = ch["offset"], ch["offset"] + ch["size"]
        if body_end is None or hi > body_end:
            body_end = hi
        if hi > len(blob) - _TRAILER_LEN:
            raise TruncatedShard("chunk extends past the footer")
        payload = blob[lo:hi]
        offs = ch["row_offsets"]
        for i, row in enumerate(range(ch["row_lo"], ch["row_hi"])):
            rows[row][ch["field"]] = payload[offs[i] : offs[i + 1]]
    return rows, {k: list(v) for k, v in footer["meta"].items()}


# ---------------------------------------------------------------------------
# store: one object key per field-chunk (cache- and billing-granular)
# ---------------------------------------------------------------------------

class ColumnarStore:
    """Chunk-granular columnar shards over any :class:`ObjectStore`.

    Each field-chunk is its own object key, so a tiered cache wrapped around
    ``base`` caches chunks (not whole items) and the simulated S3 bills only
    the chunks actually requested — pruned chunks cost zero backend bytes.
    """

    def __init__(self, base: ObjectStore, prefix: str = "columnar/train/",
                 *, cache_chunks: int = 4) -> None:
        self.base = base
        self.prefix = prefix
        self._footers: Dict[int, Dict] = {}
        self._chunk_cache: "OrderedDict[Tuple[int, str, int], bytes]" = OrderedDict()
        self._cache_cap = cache_chunks
        self._lock = threading.Lock()

    # -- keys -----------------------------------------------------------------
    def footer_key(self, shard: int) -> str:
        return f"{self.prefix}{shard:06d}/footer.rcf"

    def chunk_key(self, shard: int, field: str, ci: int) -> str:
        return f"{self.prefix}{shard:06d}/{field}/{ci:05d}.bin"

    # -- write ----------------------------------------------------------------
    def put_shard(
        self,
        shard: int,
        rows: Sequence[Dict[str, bytes]],
        meta: Optional[Dict[str, Sequence[int]]] = None,
        *,
        rows_per_chunk: int = 1,
    ) -> None:
        """Write one shard as exploded per-chunk objects + a footer object."""
        self.put_shard_blob(shard, pack_shard(rows, meta, rows_per_chunk=rows_per_chunk))

    def put_shard_blob(self, shard: int, blob: bytes) -> None:
        """Explode a packed single-file shard (e.g. a ``.rcol`` produced by
        ``scripts/convert_to_columnar.py``) into chunk-granular objects."""
        footer = read_footer(blob)
        per_field: Dict[str, int] = {}
        for ch in footer["chunks"]:
            ci = per_field.get(ch["field"], 0)
            per_field[ch["field"]] = ci + 1
            self.base.put(self.chunk_key(shard, ch["field"], ci),
                          blob[ch["offset"] : ch["offset"] + ch["size"]])
            ch["chunk_id"] = ci
        self.base.put(self.footer_key(shard), _footer_bytes(footer))
        with self._lock:
            self._footers[shard] = footer

    # -- read -----------------------------------------------------------------
    def list_shards(self) -> List[int]:
        suffix = "/footer.rcf"
        out = []
        for k in self.base.list_keys(self.prefix):
            if k.endswith(suffix):
                out.append(int(k[len(self.prefix) : -len(suffix)]))
        return sorted(out)

    def footer(self, shard: int) -> Dict:
        with self._lock:
            cached = self._footers.get(shard)
        if cached is not None:
            return cached
        footer = read_footer(self.base.get(self.footer_key(shard)))
        with self._lock:
            self._footers[shard] = footer
        return footer

    def _chunk_for_row(self, shard: int, field: str, row: int) -> Dict:
        for ch in self.footer(shard)["chunks"]:
            if ch["field"] == field and ch["row_lo"] <= row < ch["row_hi"]:
                return ch
        raise ColumnarError(f"no {field!r} chunk covers row {row} of shard {shard}")

    def _cache_get(self, key: Tuple[int, str, int]) -> Optional[bytes]:
        with self._lock:
            data = self._chunk_cache.get(key)
            if data is not None:
                self._chunk_cache.move_to_end(key)
            return data

    def _cache_put(self, key: Tuple[int, str, int], data: bytes) -> None:
        with self._lock:
            self._chunk_cache[key] = data
            while len(self._chunk_cache) > self._cache_cap:
                self._chunk_cache.popitem(last=False)

    def chunk_bytes(self, shard: int, field: str, ci: int) -> bytes:
        key = (shard, field, ci)
        data = self._cache_get(key)
        if data is None:
            data = self.base.get(self.chunk_key(shard, field, ci))
            self._cache_put(key, data)
        return data

    async def achunk_bytes(self, shard: int, field: str, ci: int) -> bytes:
        key = (shard, field, ci)
        data = self._cache_get(key)
        if data is None:
            data = await self.base.aget(self.chunk_key(shard, field, ci))
            self._cache_put(key, data)
        return data

    @staticmethod
    def _slice_row(ch: Dict, data: bytes, row: int) -> bytes:
        i = row - ch["row_lo"]
        offs = ch["row_offsets"]
        return data[offs[i] : offs[i + 1]]

    def row_bytes(self, shard: int, field: str, row: int) -> bytes:
        ch = self._chunk_for_row(shard, field, row)
        return self._slice_row(ch, self.chunk_bytes(shard, field, ch["chunk_id"]), row)

    async def arow_bytes(self, shard: int, field: str, row: int) -> bytes:
        ch = self._chunk_for_row(shard, field, row)
        data = await self.achunk_bytes(shard, field, ch["chunk_id"])
        return self._slice_row(ch, data, row)

    # -- pushdown scan ---------------------------------------------------------
    def matching_rows(self, shard: int, clauses: Sequence[Clause]) -> List[int]:
        """Rows of one shard satisfying the clause list.  Chunk statistics
        prune whole chunks first (their payloads are never requested); only
        surviving chunks get exact row-level evaluation on footer metadata."""
        footer = self.footer(shard)
        meta = footer["meta"]
        primary = footer["fields"][0]
        rows: List[int] = []
        for ch in footer["chunks"]:
            if ch["field"] != primary:
                continue
            if not chunk_matches(ch["stats"], clauses):
                continue  # pruned: zero bytes requested for this chunk
            cols = {f: np.asarray(meta[f])[ch["row_lo"] : ch["row_hi"]]
                    for f in clause_fields(clauses) if f in meta}
            if "length" in clause_fields(clauses) and "length" not in cols:
                offs = ch["row_offsets"]
                cols["length"] = np.diff(np.asarray(offs))
            mask = predicate_mask(cols, clauses) if cols else None
            for i, row in enumerate(range(ch["row_lo"], ch["row_hi"])):
                if mask is None or mask[i]:
                    rows.append(row)
        return rows


# ---------------------------------------------------------------------------
# dataset: ImageDataset semantics over columnar shards
# ---------------------------------------------------------------------------

class _RawRow(NamedTuple):
    payloads: Dict[str, bytes]  # only the projected fields
    h: int
    w: int
    c: int
    label: int
    compressed: int
    nbytes: int  # original whole-record length (decode-cost + item parity)


class ColumnarImageDataset(ImageDataset):
    """ImageNet-style dataset reading columnar shards with field projection.

    Bit-compatible with :class:`ImageDataset` over the source records: the
    pixels field holds the exact RIMG payload bytes, scalar columns (label,
    shape, original record length) live in the shard footers, and the
    inherited augment stage consumes the identical decoded record — so a
    strict-mode epoch equals the row-store epoch bit-for-bit while fetching
    only the projected payload chunks.

    ``fields`` declares what the transform needs from payload chunks
    (projection); everything predicate evaluation needs is footer-resident,
    exposed via :meth:`metadata_column` / :meth:`predicate_mask` for the
    sampler's pushdown path.
    """

    def __init__(
        self,
        store: ColumnarStore,
        num_items: int,
        *,
        out_size: int = 224,
        augment: bool = True,
        seed: int = 0,
        tracer: Tracer = NULL_TRACER,
        sim_decode_s_per_mb: float = 0.0,
        epilogue: str = "host",
        fields: Sequence[str] = ("pixels",),
    ) -> None:
        super().__init__(
            store, num_items, prefix=store.prefix, out_size=out_size,
            augment=augment, seed=seed, tracer=tracer,
            sim_decode_s_per_mb=sim_decode_s_per_mb, epilogue=epilogue,
        )
        if "pixels" not in fields:
            raise ColumnarError("the image transform requires the 'pixels' field")
        self.fields = tuple(fields)
        self._index_lock = threading.Lock()
        self._loc: Optional[np.ndarray] = None  # (num_items, 2): shard, row
        self._meta_cols: Dict[str, np.ndarray] = {}

    # -- picklability (process CPU stage): locks can't cross, the store is
    # already dropped by _StripStoreOnPickle, decode/augment never fetch -----
    def __getstate__(self) -> Dict:
        state = super().__getstate__()
        state["_index_lock"] = None
        return state

    def __setstate__(self, state: Dict) -> None:
        super().__setstate__(state)
        self._index_lock = threading.Lock()

    # -- footer index (one-time; footers are the only non-projected bytes) ----
    def _ensure_index(self) -> None:
        if self._loc is not None:
            return
        with self._index_lock:
            if self._loc is not None:
                return
            loc = np.full((self.num_items, 2), -1, dtype=np.int64)
            cols: Dict[str, List[int]] = {}
            logical_all: List[int] = []
            for shard in self.store.list_shards():
                footer = self.store.footer(shard)
                meta = footer["meta"]
                n = footer["num_rows"]
                logical = meta.get("logical", list(range(len(logical_all),
                                                         len(logical_all) + n)))
                for row, li in enumerate(logical):
                    if 0 <= li < self.num_items:
                        loc[li] = (shard, row)
                for col, vals in meta.items():
                    if col == "logical":
                        continue
                    cols.setdefault(col, []).extend(
                        (li, v) for li, v in zip(logical, vals))
                logical_all.extend(logical)
            if np.any(loc[:, 0] < 0):
                missing = int(np.sum(loc[:, 0] < 0))
                raise ColumnarError(
                    f"{missing} of {self.num_items} logical rows missing from "
                    f"columnar shards under {self.store.prefix!r}")
            meta_cols: Dict[str, np.ndarray] = {}
            for col, pairs in cols.items():
                arr = np.zeros(self.num_items, dtype=np.int64)
                for li, v in pairs:
                    if 0 <= li < self.num_items:
                        arr[li] = v
                meta_cols[col] = arr
            self._meta_cols = meta_cols
            self._loc = loc

    def metadata_column(self, name: str) -> np.ndarray:
        self._ensure_index()
        if name not in self._meta_cols:
            raise ColumnarError(f"no metadata column {name!r}; "
                                f"have {sorted(self._meta_cols)}")
        return self._meta_cols[name]

    def predicate_mask(self, clauses: Sequence[Clause]) -> np.ndarray:
        """Row mask for the sampler's predicate pushdown (footer-only: no
        payload chunk is ever fetched to evaluate a predicate)."""
        clauses = validate_clauses(clauses)
        cols = {f: self.metadata_column(f) for f in clause_fields(clauses)}
        return predicate_mask(cols, clauses)

    # -- split path ------------------------------------------------------------
    def _locate(self, index: int) -> Tuple[int, int]:
        self._ensure_index()
        shard, row = self._loc[index]
        return int(shard), int(row)

    def _raw_from_payloads(self, payloads: Dict[str, bytes], index: int) -> _RawRow:
        m = self._meta_cols
        return _RawRow(
            payloads=payloads,
            h=int(m["h"][index]), w=int(m["w"][index]), c=int(m["c"][index]),
            label=int(m["label"][index]),
            compressed=int(m["compressed"][index]),
            nbytes=int(m["nbytes"][index]),
        )

    def get_raw(self, index: int) -> _RawRow:
        shard, row = self._locate(index)
        payloads = {f: self.store.row_bytes(shard, f, row) for f in self.fields}
        return self._raw_from_payloads(payloads, index)

    async def aget_raw(self, index: int) -> _RawRow:
        shard, row = self._locate(index)
        payloads = {f: await self.store.arow_bytes(shard, f, row)
                    for f in self.fields}
        return self._raw_from_payloads(payloads, index)

    def decode_raw(self, raw: _RawRow, index: int) -> Tuple[codec.ImageRecord, int]:
        if self.sim_decode_s_per_mb:
            # same emulated decode cost as the row store charges for this
            # image (proportional to the original record, not the projection)
            time.sleep(self.sim_decode_s_per_mb * raw.nbytes / 1e6)
        payload = raw.payloads["pixels"]
        if raw.compressed:
            payload = zlib.decompress(payload)
        px = np.frombuffer(payload, dtype=np.uint8).reshape(raw.h, raw.w, raw.c)
        return codec.ImageRecord(px, raw.label), raw.nbytes


# ---------------------------------------------------------------------------
# conversion from the row-store RIMG format
# ---------------------------------------------------------------------------

def split_rimg(record: bytes) -> Tuple[Dict[str, bytes], Dict[str, int]]:
    """Split one RIMG record into its payload field + scalar metadata."""
    if record[:4] != b"RIMG":
        raise ColumnarError("not an RIMG record")
    h, w, c, label, compressed = struct.unpack("<IIIIB", record[4:_RIMG_HEADER])
    return {"pixels": record[_RIMG_HEADER:]}, {
        "h": h, "w": w, "c": c, "label": label,
        "compressed": compressed, "nbytes": len(record),
    }


def convert_image_records(
    records: Iterable[Tuple[int, bytes]],
    *,
    rows_per_shard: int = 256,
    rows_per_chunk: int = 1,
    cluster_by: Optional[str] = "label",
) -> Iterable[bytes]:
    """Convert (logical_index, RIMG bytes) records into packed shard blobs.

    ``cluster_by`` stably sorts rows by a metadata column before sharding —
    the classic columnar trick that makes chunk statistics selective (a
    label-range predicate then prunes most chunks outright).  The logical
    order is preserved in the ``logical`` metadata column, so datasets and
    samplers keep row-store index semantics regardless of physical layout.
    """
    parsed = []
    for logical, rec in records:
        fields, meta = split_rimg(rec)
        parsed.append((logical, fields, meta))
    if cluster_by is not None:
        parsed.sort(key=lambda t: (t[2][cluster_by], t[0]))
    for lo in range(0, len(parsed), rows_per_shard):
        group = parsed[lo : lo + rows_per_shard]
        rows = [fields for _, fields, _ in group]
        meta: Dict[str, List[int]] = {"logical": [g[0] for g in group]}
        for col in group[0][2]:
            meta[col] = [g[2][col] for g in group]
        yield pack_shard(rows, meta, rows_per_chunk=rows_per_chunk)


def convert_store(
    src: ObjectStore,
    num_items: int,
    dst: ColumnarStore,
    *,
    prefix: str = "imagenet/train/",
    rows_per_shard: int = 256,
    rows_per_chunk: int = 1,
    cluster_by: Optional[str] = "label",
) -> int:
    """Convert a row store of RIMG objects into columnar shards.  Returns the
    number of shards written."""
    from repro.data.imagenet_synth import item_key

    records = ((i, src.get(item_key(i, prefix))) for i in range(num_items))
    n = 0
    for n, blob in enumerate(
        convert_image_records(records, rows_per_shard=rows_per_shard,
                              rows_per_chunk=rows_per_chunk,
                              cluster_by=cluster_by), start=1):
        dst.put_shard_blob(n - 1, blob)
    return n
