from repro.data import augment, codec, dataset, imagenet_synth, shards, store  # noqa: F401
