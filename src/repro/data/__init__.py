from repro.data import augment, cache, codec, dataset, imagenet_synth, shards, store  # noqa: F401
