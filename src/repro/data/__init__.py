# Import order matters: ``store`` finishes its deferred ``cache`` re-export
# (store.py line ~322) only if it starts before ``cache`` does — importing
# ``cache`` first re-enters ``store`` through repro.core and trips the cycle.
from repro.data import augment, codec, store  # noqa: F401
from repro.data import (  # noqa: F401
    cache,
    columnar,
    dataset,
    imagenet_synth,
    shards,
)
