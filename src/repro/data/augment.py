"""Host-side augmentation pipeline (the paper's fixed ``transform``):

1) random resized crop to 224x224, 2) random horizontal flip,
3) convert to float tensor (CHW), 4) normalize.

Pure numpy, stateless given an ``np.random.Generator`` — deterministic per
(item, epoch) seed so loader implementations can be compared bit-exactly.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

IMAGENET_MEAN = np.array([0.485, 0.456, 0.406], dtype=np.float32)
IMAGENET_STD = np.array([0.229, 0.224, 0.225], dtype=np.float32)


def random_resized_crop(
    img: np.ndarray,
    rng: np.random.Generator,
    out_size: int = 224,
    scale: Tuple[float, float] = (0.08, 1.0),
    ratio: Tuple[float, float] = (3 / 4, 4 / 3),
) -> np.ndarray:
    """(H,W,C) uint8 -> (out,out,C) uint8; torchvision-style RRC with
    nearest-neighbour resize (cheap on CPU; codec cost modelled elsewhere)."""
    h, w = img.shape[:2]
    area = h * w
    for _ in range(10):
        target_area = rng.uniform(*scale) * area
        log_r = rng.uniform(np.log(ratio[0]), np.log(ratio[1]))
        r = np.exp(log_r)
        cw = int(round(np.sqrt(target_area * r)))
        ch = int(round(np.sqrt(target_area / r)))
        if 0 < cw <= w and 0 < ch <= h:
            y0 = int(rng.integers(0, h - ch + 1))
            x0 = int(rng.integers(0, w - cw + 1))
            crop = img[y0 : y0 + ch, x0 : x0 + cw]
            break
    else:  # fallback: center crop
        side = min(h, w)
        y0, x0 = (h - side) // 2, (w - side) // 2
        crop = img[y0 : y0 + side, x0 : x0 + side]
    ch, cw = crop.shape[:2]
    yi = (np.arange(out_size) * (ch / out_size)).astype(np.int64)
    xi = (np.arange(out_size) * (cw / out_size)).astype(np.int64)
    return crop[yi[:, None], xi[None, :]]


def horizontal_flip(img: np.ndarray, rng: np.random.Generator, p: float = 0.5) -> np.ndarray:
    if rng.random() < p:
        return img[:, ::-1]
    return img


def to_tensor_normalize(img: np.ndarray) -> np.ndarray:
    """(H,W,C) uint8 -> (C,H,W) float32 normalized."""
    x = img.astype(np.float32) / 255.0
    x = (x - IMAGENET_MEAN) / IMAGENET_STD
    return np.ascontiguousarray(x.transpose(2, 0, 1))


def imagenet_transform_raw(img: np.ndarray, rng: np.random.Generator, out_size: int = 224) -> np.ndarray:
    """The RNG-consuming half of the transform only: crop + flip, still uint8
    HWC.  This is where the host stages stop when the cast/normalize/layout
    tail runs on the accelerator (``kernels/ingest_norm``) — 4x fewer bytes
    cross every host boundary (shm slot, staging buffer, PCIe/ICI).  Consumes
    the generator in exactly the same order as :func:`imagenet_transform`, so
    ``to_tensor_normalize(imagenet_transform_raw(img, rng))`` is bit-identical
    to the fused host path."""
    img = random_resized_crop(img, rng, out_size)
    img = horizontal_flip(img, rng)
    return np.ascontiguousarray(img)


def imagenet_transform(img: np.ndarray, rng: np.random.Generator, out_size: int = 224) -> np.ndarray:
    return to_tensor_normalize(imagenet_transform_raw(img, rng, out_size))
