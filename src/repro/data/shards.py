"""Shard streaming (WebDataset analogue — paper §A.5).

A *shard* is a tar archive of encoded items stored as one object.  Streaming
a shard costs one large GET (amortizing per-request latency) instead of
per-item GETs — the paper shows this beats the per-item ConcurrentDataloader
on S3.  We implement:

* :func:`write_shards`   — pack a dataset into N-item tar shards.
* :class:`ShardedIterableDataset` — stream shards, unpack on the fly, yield
  decoded items (optionally shuffled within a shard buffer).
"""
from __future__ import annotations

import io
import tarfile
from typing import Iterator, List, Sequence

import numpy as np

from repro.data import codec
from repro.data.augment import imagenet_transform
from repro.data.dataset import Item, _aug_rng
from repro.data.store import ObjectStore


def shard_key(shard_idx: int, prefix: str = "shards/train/") -> str:
    return f"{prefix}{shard_idx:06d}.tar"


def write_shards(
    src: ObjectStore,
    dst: ObjectStore,
    keys: Sequence[str],
    items_per_shard: int = 256,
    prefix: str = "shards/train/",
) -> List[str]:
    """Pack the blobs at ``keys`` (in order) into tar shards in ``dst``."""
    out_keys = []
    for s, start in enumerate(range(0, len(keys), items_per_shard)):
        buf = io.BytesIO()
        with tarfile.open(fileobj=buf, mode="w") as tar:
            for k in keys[start : start + items_per_shard]:
                data = src.get(k)
                info = tarfile.TarInfo(name=k.replace("/", "__"))
                info.size = len(data)
                tar.addfile(info, io.BytesIO(data))
        key = shard_key(s, prefix)
        dst.put(key, buf.getvalue())
        out_keys.append(key)
    return out_keys


class ShardedIterableDataset:
    """Iterates decoded items by streaming tar shards from a store."""

    def __init__(
        self,
        store: ObjectStore,
        shard_keys: Sequence[str],
        out_size: int = 224,
        augment: bool = True,
        seed: int = 0,
        shuffle_buffer: int = 0,
        sim_decode_s_per_mb: float = 0.0,
    ) -> None:
        self.store = store
        self.shard_keys = list(shard_keys)
        self.out_size = out_size
        self.augment = augment
        self.seed = seed
        self.shuffle_buffer = shuffle_buffer
        self.sim_decode_s_per_mb = sim_decode_s_per_mb
        self._epoch = 0

    def set_epoch(self, epoch: int) -> None:
        self._epoch = epoch

    def _decode(self, raw: bytes, index: int) -> Item:
        if self.sim_decode_s_per_mb:
            import time

            time.sleep(self.sim_decode_s_per_mb * len(raw) / 1e6)
        rec = codec.decode_image(raw)
        if self.augment:
            rng = _aug_rng(self.seed, self._epoch, index)
            img = imagenet_transform(rec.pixels, rng, self.out_size)
        else:
            img = rec.pixels[: self.out_size, : self.out_size].transpose(2, 0, 1).astype(np.float32)
        return {"image": img, "label": np.int32(rec.label), "nbytes": np.int64(len(raw))}

    def _iter_raw(self) -> Iterator[bytes]:
        # WebDataset semantics: stream shard n while shard n+1 downloads in
        # the background (the torch DataLoader worker does this overlap).
        from concurrent.futures import ThreadPoolExecutor

        with ThreadPoolExecutor(1, thread_name_prefix="shard-prefetch") as ex:
            nxt = ex.submit(self.store.get, self.shard_keys[0]) if self.shard_keys else None
            for i, sk in enumerate(self.shard_keys):
                blob = nxt.result()
                if i + 1 < len(self.shard_keys):
                    nxt = ex.submit(self.store.get, self.shard_keys[i + 1])
                with tarfile.open(fileobj=io.BytesIO(blob), mode="r") as tar:
                    for member in tar.getmembers():
                        f = tar.extractfile(member)
                        if f is not None:
                            yield f.read()

    def __iter__(self) -> Iterator[Item]:
        rng = np.random.default_rng(self.seed + self._epoch)
        buf: List[bytes] = []
        idx = 0
        for raw in self._iter_raw():
            if self.shuffle_buffer:
                buf.append(raw)
                if len(buf) >= self.shuffle_buffer:
                    j = int(rng.integers(0, len(buf)))
                    buf[j], buf[-1] = buf[-1], buf[j]
                    yield self._decode(buf.pop(), idx)
                    idx += 1
            else:
                yield self._decode(raw, idx)
                idx += 1
        while buf:
            j = int(rng.integers(0, len(buf)))
            buf[j], buf[-1] = buf[-1], buf[j]
            yield self._decode(buf.pop(), idx)
            idx += 1
