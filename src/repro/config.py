"""Frozen-dataclass configuration system + registry.

Every run is described by a :class:`RunConfig` tree.  Configs are immutable;
``replace()`` (re-exported from dataclasses) derives variants.  Architecture
configs live in ``repro.configs`` and register themselves in ``ARCH_REGISTRY``.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass, field, replace  # noqa: F401  (replace re-exported)
from typing import Any, Callable, Mapping, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    """Attention flavour. kind: mha | gqa | mla | none (attention-free)."""

    kind: str = "gqa"
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 128
    # MLA (multi-head latent attention, MiniCPM3/DeepSeek style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    causal: bool = True
    rope: bool = True
    rope_theta: float = 10_000.0

    @property
    def q_heads_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)


@dataclass(frozen=True)
class MoEConfig:
    """Token-choice top-k mixture of experts."""

    num_experts: int = 8
    top_k: int = 2
    expert_d_ff: int = 512
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    # dispatch implementation: "einsum" (dense one-hot (g,E,C) dispatch
    # tensors — simple, but dispatch FLOPs/memory scale with E*C*d and can
    # dwarf the expert FFN for many-small-expert configs) or "gather"
    # (scatter/gather routing — O(g*K*d), the optimized path; see §Perf).
    dispatch: str = "einsum"
    # token-group size for routing; dispatch memory ~ group*E*capacity (einsum)
    # or group*top_k*d (gather).  Sized per-arch so groups fit VMEM-scale.
    group_size: int = 4096
    # pad the stacked expert weights to this count (0 = no padding) so the
    # expert dim divides the TP/EP mesh axis: 40 or 60 experts cannot shard
    # over a 16-wide axis and would silently replicate (16x compute waste);
    # padded experts receive no tokens and exist only for divisibility.
    pad_experts_to: int = 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective scan (for jamba) — d_inner = expand * d_model."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 'Finch' data-dependent decay."""

    head_dim: int = 64
    decay_lora: int = 64
    token_shift: bool = True


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "decoder"  # decoder | encdec | resnet | rwkv | hybrid
    num_layers: int = 4
    d_model: int = 256
    d_ff: int = 1024
    vocab_size: int = 32_000
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    mlp: str = "swiglu"  # swiglu | relu2 | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    # hybrid (jamba): per-layer mixer pattern, period repeats over num_layers.
    # entries: "attn" | "mamba"; moe_period: every k-th layer uses MoE MLP.
    hybrid_attn_period: int = 0  # 0 = not hybrid; jamba: 8 with attn at index 3
    hybrid_attn_index: int = 3
    moe_every_k: int = 0  # 0 = never; jamba: 2
    # enc-dec
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0  # whisper: 1500 frames
    # vlm stub
    num_patch_tokens: int = 0  # internvl: 1024 patch embeddings
    frontend_dim: int = 0  # dim of precomputed frontend embeddings (0 = d_model)
    # resnet
    resnet_blocks: Tuple[int, ...] = ()
    resnet_width: int = 64
    num_classes: int = 1000
    image_size: int = 224
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    # which attention implementation the model uses ("ref" | "pallas")
    attention_impl: str = "ref"

    @property
    def head_dim(self) -> int:
        a = self.attention
        if a is None:
            return 0
        if a.kind == "mla":
            return a.qk_nope_head_dim + a.qk_rope_head_dim
        return a.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops accounting)."""
        from repro.models.counting import count_params  # lazy, avoids cycle

        return count_params(self)


# ---------------------------------------------------------------------------
# Input shapes (assigned per-arch shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: Mapping[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}

# ---------------------------------------------------------------------------
# Data pipeline configuration (the paper's knobs, Table 4/5)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CacheConfig:
    """Cache-tier block of a :class:`StoreConfig` (paper §2.4; Varnish
    analogue).  When both ``memory_bytes`` and ``dir`` are set, build_store
    assembles one two-tier TieredCacheStore (memory LRU over bounded disk)
    instead of nesting single-tier caches."""

    memory_bytes: int = 0  # memory tier capacity; 0 = no memory tier
    dir: str = ""  # disk tier directory; "" = no disk tier
    disk_bytes: int = 0  # disk tier capacity; 0 = unbounded (legacy)
    # memory-tier lock striping.  Default 1 = exact global LRU with items
    # cacheable up to the full capacity (the legacy CachedStore semantics).
    # Raising it trades strict LRU for less lock contention AND caps the
    # largest cacheable item at memory_bytes // shards — opt in only
    # when single objects are far smaller than the memory budget.
    shards: int = 1
    # disk-tier admission: admit-all | size-threshold | second-hit | tinylfu
    admission: str = "admit-all"
    admission_max_item_bytes: int = 1 << 20  # size-threshold policy cutoff
    # multi-host disk-tier coordination (repro.core.coord) when several
    # processes/hosts point ``dir`` at one shared directory:
    #   ""        — off: in-process accounting only (single-host, the default)
    #   "journal" — shared accounting: one fcntl-locked byte journal under
    #               dir/.coord bounds the tier across all writers
    #   "shard"   — partitioned keyspace: this host only caches keys where
    #               host_shard(key, n_hosts) == host_id (capacity is per-host)
    #               but opportunistically reads peers' entries off the shared
    #               disk
    coord: str = ""
    coord_host_id: int = 0
    coord_num_hosts: int = 1


@dataclass(frozen=True)
class StoreConfig:
    kind: str = "s3sim"  # memory | localfs | s3sim | synth
    root: str = ""  # for localfs
    # SimulatedS3 latency model (lognormal) — defaults calibrated so that the
    # paper's phenomenology reproduces at benchmark scale (see DESIGN.md §2).
    latency_mean_s: float = 0.08
    latency_sigma: float = 0.5
    bandwidth_per_conn: float = 25e6  # bytes/s per connection
    nic_bandwidth: float = 1.2e9  # bytes/s aggregate
    max_connections: int = 256
    failure_rate: float = 0.0
    # congestion collapse model: when the NIC is oversubscribed (more active
    # transfers than nic_bandwidth / bandwidth_per_conn supports), each GET's
    # service time is additionally scaled by (oversubscription)**overload_penalty
    # — the queueing/bufferbloat tail real links exhibit.  0 = off (the
    # legacy monotone model, where extra concurrency never hurts).
    overload_penalty: float = 0.0
    # cache tiers (see CacheConfig).  The historical flat ``cache_*`` kwargs
    # still construct the nested form through a deprecation shim; reads of
    # the old flat names delegate below.
    cache: CacheConfig = CacheConfig()

    # -- legacy flat reads (the write path is shimmed in __init__) ----------
    @property
    def cache_bytes(self) -> int:
        return self.cache.memory_bytes

    @property
    def cache_dir(self) -> str:
        return self.cache.dir

    @property
    def disk_cache_bytes(self) -> int:
        return self.cache.disk_bytes

    @property
    def cache_shards(self) -> int:
        return self.cache.shards

    @property
    def cache_admission(self) -> str:
        return self.cache.admission

    @property
    def admission_max_item_bytes(self) -> int:
        return self.cache.admission_max_item_bytes

    @property
    def cache_coord(self) -> str:
        return self.cache.coord

    @property
    def cache_coord_host_id(self) -> int:
        return self.cache.coord_host_id

    @property
    def cache_coord_num_hosts(self) -> int:
        return self.cache.coord_num_hosts


# Deprecation shim: StoreConfig grew 9 flat cache fields over PRs 2-3; they
# now live in CacheConfig.  Old call sites keep working — each flat kwarg
# warns once and is folded into the nested sub-config — and
# ``dataclasses.replace`` passes the nested field straight through, so the
# shim never re-fires on derived configs.  Migration note in README
# ("Online serving read path").
_LEGACY_CACHE_KWARGS = {
    "cache_bytes": "memory_bytes",
    "cache_dir": "dir",
    "disk_cache_bytes": "disk_bytes",
    "cache_shards": "shards",
    "cache_admission": "admission",
    "admission_max_item_bytes": "admission_max_item_bytes",
    "cache_coord": "coord",
    "cache_coord_host_id": "coord_host_id",
    "cache_coord_num_hosts": "coord_num_hosts",
}

_store_config_init = StoreConfig.__init__


@functools.wraps(_store_config_init)
def _store_config_shim_init(self, *args: Any, **kwargs: Any) -> None:
    legacy = {}
    for flat, nested in _LEGACY_CACHE_KWARGS.items():
        if flat in kwargs:
            warnings.warn(
                f"StoreConfig({flat}=...) is deprecated and will be removed;"
                f" pass cache=CacheConfig({nested}=...) instead",
                DeprecationWarning, stacklevel=2,
            )
            legacy[nested] = kwargs.pop(flat)
    if legacy:
        cache = kwargs.get("cache")
        kwargs["cache"] = replace(
            cache if cache is not None else CacheConfig(), **legacy
        )
    _store_config_init(self, *args, **kwargs)


StoreConfig.__init__ = _store_config_shim_init  # type: ignore[method-assign]


@dataclass(frozen=True)
class AutotuneConfig:
    """Closed-loop knob control for the loader (online analogue of the
    Fig. 10/11 grid search).

    A hill-climbing controller with hysteresis observes windowed throughput
    (``Tracer`` get_batch spans) plus store/fetch signals and adjusts, at a
    safe between-batch boundary: per-worker fetch concurrency, the prefetch
    outstanding window, hedging on/off, and (when attached) the device
    prefetch ring depth.  All knobs are clamped to the bounds below.
    """

    enabled: bool = False
    # measurement window: closes after at least `interval_batches` batches
    # AND `min_window_s` wall time.  The wall-time floor matters: delivery is
    # bursty (the reorder buffer releases several batches at once), so a
    # batch-count-only window can span microseconds and measure buffer pops
    # instead of pipeline production rate.
    interval_batches: int = 4
    min_window_s: float = 0.2
    # measured windows to observe before the first probe (the first window is
    # warped by the prefetch burst + worker startup)
    warmup_windows: int = 1
    # accept a move only if windowed throughput improves by this fraction;
    # revert if it regresses by more than it (hysteresis dead-band)
    rel_improvement: float = 0.05
    # knob bounds (inclusive)
    min_fetch_workers: int = 1
    max_fetch_workers: int = 64
    min_outstanding: int = 1
    max_outstanding: int = 64
    min_device_prefetch: int = 1
    max_device_prefetch: int = 8
    # per-knob coarse->fine step schedule for integer knobs: each knob starts
    # at the first (coarse) factor and drops to the next finer one after a
    # revert/hold on that knob; a rearm (regime change) resets to coarse.
    # () derives (2 * step_factor, step_factor) so a bare step_factor keeps
    # its legacy meaning as the *fine* step.
    step_schedule: Tuple[int, ...] = ()
    # multiplicative fine step for integer knobs (value *= step / value //= step)
    step_factor: int = 2
    # allow the controller to trial-toggle hedged requests once concurrency
    # knobs have plateaued (threaded impl only)
    tune_hedge: bool = False
    # consecutive plateau windows before the controller goes quiescent
    patience: int = 3
    # jump back to the best settled state when a window collapses below half
    # of its throughput.  Right for stationary measurement (the collapse IS
    # the walk's fault); disable when the environment itself is non-stationary
    # (shared CPUs, phase-shifting load) — there a collapse says nothing
    # about the knobs and restoring just thrashes them.
    collapse_restore: bool = True
    # exploration heartbeat: while quiescent, re-probe once every this many
    # windows (0 = off).  Escapes premature parking after early noise
    # reverts — a collapse-based re-arm alone cannot detect "parked at a
    # stable but suboptimal point".  A failed heartbeat probe re-quiesces
    # immediately; an accepted one resumes full climbing.
    reprobe_windows: int = 8
    # accelerator-utilization gate: when the controller has a utilization
    # signal (Trainer wires repro.core.utilization.recent_busy_fraction) and
    # the training step is busier than this fraction, upward probes are
    # skipped — don't buy loader throughput the accelerator can't eat.
    # 0 disables the gate.
    util_gate: float = 0.9
    # cache-tier knobs (attached when the dataset's store stack contains a
    # TieredCacheStore).  Capacity knobs exist only when the matching
    # max_*_cache_bytes names an explicit ceiling ABOVE the configured
    # capacity (default 0 = no capacity knob): growth is almost always
    # throughput-positive, so a default ceiling would let the hill climber
    # silently walk a cache the user sized for their RAM/disk up to it.
    # The admission-policy knob is attached whenever a disk tier exists.
    tune_cache: bool = True
    min_memory_cache_bytes: int = 1 << 20
    max_memory_cache_bytes: int = 0
    min_disk_cache_bytes: int = 1 << 22
    max_disk_cache_bytes: int = 0
    tune_admission: bool = True
    # cache-knob cadence.  Capacity knobs pay off on *epoch* timescales in
    # full-pass regimes (a shuffled pass has no intra-epoch repeats, so a
    # bigger cache only shows up one epoch later — see bench_cache):
    #   "batch" — cache knobs ride the per-batch controller (legacy; right
    #             for within-epoch-repeat workloads)
    #   "epoch" — the loader runs a second controller for the cache knobs,
    #             fed once per completed epoch, judging on
    #             cache_epoch_windows-epoch throughput windows
    cache_cadence: str = "batch"
    cache_epoch_windows: int = 2
    # multi-host cooperative tuning (repro.core.coord.UpProbeLease): when
    # coord_dir names a directory shared by co-located hosts, upward
    # concurrency/hedging probes require holding the fleet-wide up-probe
    # lease — one tenant probes a saturated NIC while the others hold or
    # refine downward.  "" = off (single-host, the default; behaviour is
    # bit-identical to a lease-free controller).  A crashed holder's lease
    # expires after coord_ttl_s.
    coord_dir: str = ""
    coord_ttl_s: float = 30.0
    # staged-pipeline stage knobs (LoaderConfig.pipeline): CPU executor width
    # and the fetch->decode queue depth.  The IO executor reuses the
    # min/max_fetch_workers bounds above — it gates the same resource (in-
    # flight GETs) the per-worker fetch pool gated in the legacy path.
    min_cpu_workers: int = 1
    max_cpu_workers: int = 32
    min_stage_queue: int = 4
    max_stage_queue: int = 512
    # shm-transport slab pressure knob (PipelineConfig.transport="shm"): the
    # controller caps how many of the preallocated slots each worker may use
    # (live, via a slab_cap message) — fewer slots = less memory pinned and
    # earlier pickle fallback; more slots = headroom for bursty decode.
    min_slab_slots: int = 4
    max_slab_slots: int = 512
    # budget co-tuning (staged pipeline + split datasets only).  0 keeps the
    # independent io_workers/cpu_workers knobs.  >0 fixes the TOTAL executor
    # width at thread_budget and replaces those two knobs with one coupled
    # "io_cpu_split" knob (value = IO width; CPU width = budget - value):
    # instead of inflating both stages independently, the controller probes
    # "where does the next thread help" under a fixed parallelism budget —
    # the right question on a host whose cores are already spoken for.
    thread_budget: int = 0
    # with thread_budget set and a process-capable dataset (split path +
    # picklable), also expose the CPU executor KIND (thread vs spawn-process)
    # as a binary knob so the controller can buy the GIL escape only when the
    # decode actually holds the GIL.
    tune_cpu_executor: bool = True
    # -- objective ----------------------------------------------------------
    # "throughput" (default): score = windowed items/s (training loaders).
    # "latency": score = latency_target_s / windowed latency_quantile — the
    # serving read path feeds per-request latencies via on_request() and the
    # same hill climber MINIMIZES the tail by maximizing the inverted score.
    objective: str = "throughput"
    latency_target_s: float = 0.5  # the SLO target the p-quantile is scored against
    latency_quantile: float = 0.99
    # serve read-path knob bounds (objective="latency"): SLO hedge delay and
    # the single-flight coalesce result-hold window, in milliseconds.
    min_hedge_delay_ms: int = 1
    max_hedge_delay_ms: int = 5_000
    min_coalesce_ms: int = 1
    max_coalesce_ms: int = 5_000
    # sharded-delivery lane-skew gate: when stage_stats()["delivery"] reports
    # lane_skew (max-min composed batches across lanes) at or above this many
    # batches, upward probes are skipped — widening a pipeline whose lanes
    # already diverge just deepens the straggler imbalance; only downward
    # refinement runs until the lanes re-converge.  0 disables the gate.
    skew_gate: int = 0
    # shuffle-entropy floor (reorder="window" pipelines): when
    # stage_stats()["shuffle"] reports within-batch entropy below this value
    # (normalized 0..1), upward probes of the reorder_window knob are
    # skipped — a wider window buys throughput by stratifying batches by
    # completion time, and this floor makes that randomness loss a measured,
    # gated trade instead of an invisible one.  0.0 disables the gate.
    min_shuffle_entropy: float = 0.0
    # reorder_window knob bounds (window-mode pipelines only)
    min_reorder_window: int = 1
    max_reorder_window: int = 64
    # -- cooperative down-shedding (repro.core.coord.CongestionBoard) -------
    # AIMD across the fleet: a host whose window collapses below
    # shed_collapse_fraction of its best settled throughput posts a shed
    # event to coord_dir's CongestionBoard, and EVERY host (poster included)
    # multiplicatively cuts its concurrency knobs by shed_md_factor, holds
    # shed_hold_windows windows, then recovers additively toward the
    # pre-shed values over shed_recover_windows windows.  Per-host hill
    # climbing only gives back its own last probe step under collapse; the
    # board is what makes the whole fleet back off together.  0.0 = off
    # (the default: existing coord_dir fleets keep lease-gating only).
    # Requires coord_dir.
    shed_collapse_fraction: float = 0.0
    shed_md_factor: float = 0.5  # multiplicative-decrease factor per shed
    shed_hold_windows: int = 2  # windows to sit at the cut point
    shed_recover_windows: int = 8  # windows to climb back additively
    # fleet-wide shed rate limit: a collapse seen by N hosts injects ONE
    # shed event, not N stacked halvings (enforced under the board lock)
    shed_min_interval_s: float = 5.0


@dataclass(frozen=True)
class PipelineConfig:
    """Staged streaming pipeline (repro.core.pipeline): replaces the
    worker/fetcher path with an explicit stage graph (fetch-raw -> decode ->
    augment -> collate) on dedicated IO and CPU executors with sample-level
    out-of-order completion.  ``enabled=False`` (the default) keeps the
    legacy path untouched and bit-identical; the sub-config is truthy iff
    enabled, so ``if cfg.pipeline:`` reads the same either way."""

    enabled: bool = False
    # batch-assembly policy:
    #   "strict" — every batch holds exactly its sampler-assigned samples in
    #              sampler order, delivered in batch order (bit-identical to
    #              the legacy loader's stream)
    #   "window" — within each aligned group of `reorder_window` batches,
    #              batch slots are filled by whichever of the group's samples
    #              finish first (first-N-ready composition); a straggler only
    #              delays the last batch of its group, not its own batch
    reorder: str = "strict"
    reorder_window: int = 4
    # stage sizing.  0 = derive: io_workers defaults to
    # num_workers * num_fetch_workers (the legacy loader's total fetch
    # thread count, so pipeline-vs-legacy comparisons run at equal
    # concurrency); cpu_workers defaults to 4.
    io_workers: int = 0
    cpu_workers: int = 0
    # CPU (decode+augment) stage executor:
    #   "thread"  — gated thread pool (legacy; right for GIL-releasing C
    #               decoders like libjpeg, zero serialization cost)
    #   "process" — spawn-based worker-process pool (escapes the GIL for
    #               pure-Python/GIL-holding decoders; requires the dataset's
    #               split path AND a picklable dataset — see README).  The
    #               pool persists across epochs on the loader; a crashed
    #               worker is respawned and only its in-flight sample is
    #               retried.  Datasets without the split path fall back to
    #               monolithic fetch exactly as with "thread".
    cpu_executor: str = "thread"
    # bounded fetch->decode queue (in samples).  A full queue blocks the IO
    # threads that try to feed it — that stall is the pipeline's
    # backpressure, and the depth is an autotune knob.
    stage_queue_depth: int = 64
    # process-stage result transport (cpu_executor="process" only):
    #   "pipe" — every decoded sample is pickled through the result pipe
    #            (legacy; fine at tens of kB, two full copies per sample)
    #   "shm"  — workers write decoded arrays into a preallocated per-worker
    #            shared-memory slab (slot-granular, generation-counted) and
    #            ship only (slot, dtype, shape, offset) handles over the
    #            pipe; the parent reads zero-copy views.  Oversized/ragged
    #            samples and slab pressure fall back to pickle per sample.
    transport: str = "pipe"
    # shm slab sizing: slots per worker slab and bytes per slot.  A slot
    # must hold one whole decoded sample (all arrays, padded to 64B each);
    # bigger samples take the pickle fallback.  slab_slots is an autotune
    # knob (AutotuneConfig.min/max_slab_slots).
    slab_slot_bytes: int = 1 << 20
    slab_slots: int = 32
    # pinned host staging (repro.core.staging): >0 collates batches directly
    # into a pool of this many reusable page-aligned host buffers that the
    # device-prefetch ring hands to device_put and recycles after transfer,
    # replacing the per-batch np.stack allocation+copy.  Only engages for
    # the default collate; 0 = off.
    staging_buffers: int = 0

    def __bool__(self) -> bool:
        return self.enabled


@dataclass(frozen=True)
class DeliverySpec:
    """How assembled batches reach the consumer (repro.core.delivery).

    * ``host`` (default) — one host-resident numpy batch per step; the
      consumer (or the device-prefetch ring) moves it to devices.
    * ``sharded`` — one assembler lane per addressable slice of ``mesh``
      along ``axis``; each lane collates its contiguous sub-batch and
      device-puts it to its own device(s), and the lanes are composed into a
      device-sharded global ``jax.Array`` via
      ``jax.make_array_from_single_device_arrays`` (process-local shards
      only — no gather).  Requires the staged pipeline with strict reorder.

    ``mesh`` is a ``jax.sharding.Mesh`` (kept opaque here so the config
    layer stays jax-free); ``coord_dir`` names a directory shared by
    co-located hosts so per-lane resume cursors are pinned fleet-wide
    (repro.core.delivery.ShardCursorBoard over the PR-3 coord layer)."""

    kind: str = "host"  # host | sharded
    axis: str = "data"  # mesh axis the global batch dim shards over
    mesh: Any = None  # jax.sharding.Mesh (required for kind="sharded")
    coord_dir: str = ""  # multi-host cursor alignment ("" = single host)

    @staticmethod
    def host() -> "DeliverySpec":
        return DeliverySpec()

    @staticmethod
    def sharded(mesh: Any, axis: str = "data",
                coord_dir: str = "") -> "DeliverySpec":
        return DeliverySpec(kind="sharded", axis=axis, mesh=mesh,
                            coord_dir=coord_dir)


@dataclass(frozen=True)
class ElasticConfig:
    """Elastic fleet membership + work claiming (repro.core.elastic).

    When enabled, the loader joins a lease-based ``MembershipBoard`` under
    ``coord_dir`` and replaces static batch sharding with claim-based
    scheduling over an ``EpochShardBoard``: the epoch's batches are split
    into shards of ``shard_batches`` that live hosts claim under TTL
    leases, so hosts may join, leave, or crash mid-epoch and the *union*
    of delivered batches still covers the epoch exactly (a dead host's
    in-flight shard is resumed by a survivor at its last confirmed batch —
    at-least-once for the unconfirmed tail, never lost).  The sub-config
    is truthy iff enabled, so ``if cfg.elastic:`` reads naturally."""

    enabled: bool = False
    coord_dir: str = ""  # shared directory (required when enabled)
    lease_ttl_s: float = 10.0  # membership + shard-claim lease TTL
    heartbeat_interval_s: float = 2.0  # max staleness of our own lease
    shard_batches: int = 8  # claim granularity (batches per shard)
    claim_poll_s: float = 0.05  # wait between claim attempts when starved

    def __bool__(self) -> bool:
        return bool(self.enabled)


_PREDICATE_OPS = ("==", "!=", "<", "<=", ">", ">=", "in", "not_in")


@dataclass(frozen=True)
class SamplerPredicate:
    """Callable-free sampler predicate for columnar pushdown.

    ``clauses`` is an AND-list of ``(field, op, value)`` tuples over a
    dataset's metadata columns, e.g. ``(("label", "in", (0, 1, 2)),
    ("length", "<", 65536))``.  Tuples (not callables) keep predicates
    picklable, checkpointable, and evaluable against chunk statistics —
    the loader hands them to the dataset's ``predicate_mask`` so rejected
    rows' bytes are never requested from the store.

    ``schedule`` optionally re-declares the clause list per epoch for
    curriculum filtering: ``((epoch, clauses), ...)`` — the entry with the
    largest ``epoch <= current`` wins; before the first entry, ``clauses``
    applies.  Epoch masks are pure functions of (predicate, epoch), so
    strict-mode resume cursors replay the identical filtered stream.
    """

    clauses: Tuple[Tuple[str, str, Any], ...] = ()
    schedule: Tuple[Tuple[int, Tuple[Tuple[str, str, Any], ...]], ...] = ()

    def __post_init__(self) -> None:
        for cls in (self.clauses, *(cl for _, cl in self.schedule)):
            for c in cls:
                if len(c) != 3 or not isinstance(c[0], str) or c[1] not in _PREDICATE_OPS:
                    raise ValueError(
                        f"predicate clause must be (field, op, value) with op "
                        f"in {_PREDICATE_OPS}, got {c!r}")
                if callable(c[2]):
                    raise ValueError(f"predicate values must be data, not "
                                     f"callables: {c!r}")

    def clauses_for_epoch(self, epoch: int) -> Tuple[Tuple[str, str, Any], ...]:
        out = self.clauses
        for e, cls in sorted(self.schedule, key=lambda t: t[0]):
            if epoch >= e:
                out = tuple(cls)
        return out

    def __bool__(self) -> bool:
        return bool(self.clauses or self.schedule)


@dataclass(frozen=True)
class LoaderConfig:
    impl: str = "threaded"  # vanilla | threaded | asyncio
    batch_size: int = 256
    num_workers: int = 4
    prefetch_factor: int = 4
    num_fetch_workers: int = 16
    batch_pool: int = 0  # >0 enables batch disassembly (threaded impl only)
    lazy_init: bool = True
    pin_device: bool = False  # device prefetch ring (batch_to_device overlap)
    device_prefetch: int = 2
    drop_last: bool = True
    shuffle: bool = True
    seed: int = 0
    # straggler mitigation: hedge a fetch when it exceeds p95 * hedge_factor
    hedge_requests: bool = False
    hedge_factor: float = 3.0
    hedge_min_s: float = 0.05
    timeout_s: float = 120.0
    # staged streaming pipeline (see PipelineConfig).  The legacy flat
    # kwargs (pipeline=<bool>, reorder=..., io_workers=..., ...) still
    # construct the nested form through a deprecation shim; reads of the old
    # flat names delegate below.
    pipeline: PipelineConfig = PipelineConfig()
    # batch delivery contract (see DeliverySpec): host-resident batches
    # (default) or device-sharded global arrays assembled per mesh lane
    delivery: DeliverySpec = DeliverySpec()
    # columnar predicate pushdown (see SamplerPredicate): filters the epoch
    # stream at the sampler via dataset metadata, so rejected rows are never
    # fetched.  None = unfiltered.  Requires a dataset with predicate
    # metadata (repro.data.columnar.ColumnarImageDataset).
    sampler: Optional[SamplerPredicate] = None
    # online knob control (off by default: behaviour is bit-identical to a
    # statically configured loader when disabled)
    autotune: AutotuneConfig = AutotuneConfig()
    # elastic fleet membership + claim-based batch scheduling (see
    # ElasticConfig).  Off by default: static host_id/num_hosts sharding.
    elastic: ElasticConfig = ElasticConfig()

    # -- legacy flat reads (the write path is shimmed in __init__) ----------
    @property
    def reorder(self) -> str:
        return self.pipeline.reorder

    @property
    def reorder_window(self) -> int:
        return self.pipeline.reorder_window

    @property
    def io_workers(self) -> int:
        return self.pipeline.io_workers

    @property
    def cpu_workers(self) -> int:
        return self.pipeline.cpu_workers

    @property
    def cpu_executor(self) -> str:
        return self.pipeline.cpu_executor

    @property
    def stage_queue_depth(self) -> int:
        return self.pipeline.stage_queue_depth


# Deprecation shim: LoaderConfig grew ~7 flat pipeline fields over PRs 4-5;
# they now live in PipelineConfig.  Old call sites keep working — each flat
# kwarg warns once and is folded into the nested sub-config — and
# ``dataclasses.replace`` passes the nested fields straight through, so the
# shim never re-fires on derived configs.  Removal note in README
# ("Sharded delivery & the loader API").
_LEGACY_PIPELINE_KWARGS = (
    "reorder", "reorder_window", "io_workers", "cpu_workers",
    "cpu_executor", "stage_queue_depth",
)

_loader_config_init = LoaderConfig.__init__


@functools.wraps(_loader_config_init)
def _loader_config_shim_init(self, *args: Any, **kwargs: Any) -> None:
    legacy = {}
    for name in _LEGACY_PIPELINE_KWARGS:
        if name in kwargs:
            warnings.warn(
                f"LoaderConfig({name}=...) is deprecated and will be removed;"
                f" pass pipeline=PipelineConfig({name}=...) instead",
                DeprecationWarning, stacklevel=2,
            )
            legacy[name] = kwargs.pop(name)
    pipe = kwargs.get("pipeline")
    if isinstance(pipe, bool):
        warnings.warn(
            "LoaderConfig(pipeline=<bool>) is deprecated and will be removed;"
            " pass pipeline=PipelineConfig(enabled=...) instead",
            DeprecationWarning, stacklevel=2,
        )
        kwargs["pipeline"] = PipelineConfig(enabled=pipe, **legacy)
    elif legacy:
        kwargs["pipeline"] = replace(
            pipe if pipe is not None else PipelineConfig(), **legacy
        )
    _loader_config_init(self, *args, **kwargs)


LoaderConfig.__init__ = _loader_config_shim_init  # type: ignore[method-assign]


@dataclass(frozen=True)
class TenantPolicy:
    """Per-tenant admission/fairness policy on the serving read path.

    Budgets meter the *shared* tiers: bytes served from the disk tier or
    fetched from origin debit the tenant's token bucket (memory-tier hits are
    free — they contend on nothing).  A tenant over budget blocks before
    issuing backend I/O until the bucket refills, so one hot tenant cannot
    starve the rest of disk/NIC service.  ``tenant="*"`` is the default
    policy for tenants without an explicit entry."""

    tenant: str = "*"
    rate_bytes_per_s: float = 0.0  # sustained budget; 0 = unmetered
    burst_bytes: int = 0  # bucket depth; 0 derives one second of rate
    max_inflight: int = 0  # concurrent backend fetches; 0 = unlimited


@dataclass(frozen=True)
class ServeSpec:
    """Online-serving surface (repro.serve): inference engine slots plus the
    multi-tenant read path (single-flight coalescing, tenant fairness, SLO
    hedging — see README "Online serving read path").

    The historical flat ``ServeEngine(cfg, params, num_slots=..., max_len=...)``
    kwargs still work through a warn-once deprecation shim; new call sites
    pass ``spec=ServeSpec(...)`` and ``replace()`` derives variants silently.
    """

    # -- engine (continuous-batching slots) ---------------------------------
    num_slots: int = 4
    max_len: int = 512
    # -- read path ----------------------------------------------------------
    # single-flight coalescing: concurrent misses on one key share a single
    # backend fetch, and the completed result is held for this window so
    # bursts arriving just after completion still coalesce.  0 disables
    # coalescing entirely (every miss fetches — the uncoalesced baseline).
    coalesce_window_s: float = 0.05
    # hedged reads: "off" | "fixed" (constant hedge_delay_s) | "slo" (delay
    # derived from the live latency distribution vs slo_p99_s: fire the
    # duplicate at max(hedge_min_s, slo_p99_s - p50) so it can still finish
    # inside the SLO).
    hedge: str = "off"
    hedge_delay_s: float = 0.1  # "fixed" mode delay
    hedge_min_s: float = 0.005  # floor under the derived "slo" delay
    slo_p99_s: float = 0.5  # tail-latency objective the path is tuned against
    hedge_budget_fraction: float = 0.05  # max hedges per request, sustained
    # global backend concurrency cap (leader + hedge fetches)
    max_inflight: int = 64
    # per-tenant fairness policies; ("*" entry = default for unlisted tenants)
    tenants: Tuple[TenantPolicy, ...] = ()
    # latency-objective closed-loop control (AutotuneConfig.objective must be
    # "latency" when enabled here): tunes hedge delay, coalesce window, and —
    # when the store stack has a TieredCacheStore — the cache knobs against
    # the p99 target.
    autotune: AutotuneConfig = AutotuneConfig()


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"  # adamw | adafactor | sgd
    learning_rate: float = 1e-3
    weight_decay: float = 1e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    schedule: str = "cosine"  # cosine | constant | linear
    total_steps: int = 1000
    microbatches: int = 1  # grad-accumulation via lax.scan
    grad_compression: str = "none"  # none | bf16 | int8_ef
    checkpoint_every: int = 200
    keep_checkpoints: int = 3
    log_every_n_steps: int = 10
    label_smoothing: float = 0.0


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig = TRAIN_4K
    loader: LoaderConfig = LoaderConfig()
    store: StoreConfig = StoreConfig()
    train: TrainConfig = TrainConfig()
    mesh: MeshConfig = SINGLE_POD_MESH
    serve: ServeSpec = ServeSpec()


# public surface (tests/test_api_surface.py pins names + signatures)
__all__ = [
    "AttentionConfig",
    "AutotuneConfig",
    "CacheConfig",
    "DeliverySpec",
    "ElasticConfig",
    "LoaderConfig",
    "MeshConfig",
    "ModelConfig",
    "MoEConfig",
    "PipelineConfig",
    "RunConfig",
    "RWKVConfig",
    "SamplerPredicate",
    "ServeSpec",
    "ShapeConfig",
    "SSMConfig",
    "StoreConfig",
    "TenantPolicy",
    "TrainConfig",
    "arch_shapes",
    "get_arch",
    "list_archs",
    "register_arch",
    "replace",
]

# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

ARCH_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
SMOKE_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register_arch(name: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]) -> None:
    ARCH_REGISTRY[name] = full
    SMOKE_REGISTRY[name] = smoke


def get_arch(name: str, smoke: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  triggers registration

    reg = SMOKE_REGISTRY if smoke else ARCH_REGISTRY
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(reg)}")
    return reg[name]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(ARCH_REGISTRY)


def arch_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    """Which of the four assigned shapes apply to this architecture.

    long_500k needs sub-quadratic attention: run for SSM/hybrid archs
    (rwkv6, jamba), skip for pure full-attention archs (noted in DESIGN.md).
    resnet uses its own image shapes and is the paper's own model, not one of
    the 40 assigned cells.
    """
    if cfg.family == "resnet":
        return [TRAIN_4K]
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.family in ("rwkv", "hybrid"):
        shapes.append(LONG_500K)
    return shapes
