"""Atomic, sharded, resumable checkpointing.

Layout (one directory per step)::

    <root>/step_000200.tmp-<pid>/   (written)
        arrays_h{host}.npz          (this host's addressable shards)
        meta.json                   (step, epoch, loader state, tree structure)
    <root>/step_000200/             (atomic rename on completion)

* atomic: readers never see a partial checkpoint (tmp dir + ``os.replace``).
* sharded: each host writes only its addressable data (on CPU CI there is
  one host; the path is the same).
* resumable: loader/sampler state rides along, so restart reproduces the
  exact item order (paired with the deterministic sampler).
* async: ``save(..., blocking=False)`` snapshots to host RAM then writes in
  a background thread — training continues (checkpoint/compute overlap).
* retention: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}

    def visit(kp, x):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        flat[path] = np.asarray(x)

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def _treedef_paths(tree: Any) -> List[str]:
    paths = []
    jax.tree_util.tree_map_with_path(
        lambda kp, x: paths.append(
            "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        ),
        tree,
    )
    return paths


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, host_id: int = 0) -> None:
        self.root = root
        self.keep = keep
        self.host_id = host_id
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- paths ---------------------------------------------------------------
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and ".tmp" not in d:
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ----------------------------------------------------------------
    def save(
        self,
        step: int,
        state: Any,
        extra_meta: Optional[Dict[str, Any]] = None,
        blocking: bool = True,
    ) -> None:
        self.wait()  # one async save in flight at a time
        # snapshot to host RAM first (cheap on CPU; device->host on TPU)
        flat = _flatten(jax.tree.map(lambda x: np.asarray(x), state))
        meta = {"step": int(step), "extra": extra_meta or {}}

        def write():
            try:
                tmp = self._dir(step) + f".tmp-{os.getpid()}"
                os.makedirs(tmp, exist_ok=True)
                np.savez(os.path.join(tmp, f"arrays_h{self.host_id}.npz"), **flat)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                final = self._dir(step)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, name="ckpt-writer", daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint failed") from err

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``template`` (shapes must match)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._dir(step)
        with np.load(os.path.join(d, f"arrays_h{self.host_id}.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        paths = _treedef_paths(template)
        missing = [p for p in paths if p not in arrays]
        if missing:
            raise KeyError(f"checkpoint missing {len(missing)} arrays, e.g. {missing[:3]}")
        flat_template, tdef = jax.tree.flatten(template)
        restored = tdef.unflatten([arrays[p] for p in paths])
        # dtype/shape validation against the template
        def check(t, r):
            if hasattr(t, "shape") and tuple(t.shape) != tuple(r.shape):
                raise ValueError(f"shape mismatch {t.shape} vs {r.shape}")
            return r

        restored = jax.tree.map(check, template, restored)
        return restored, meta
