"""Trainer with hooks/callbacks — the "Lightning analogue" (paper §A.3).

The paper found Lightning's callback/logging machinery (GPUStatsMonitor +
aggressive ``log_every_n_steps``) responsible for a large Torch-vs-Lightning
gap.  We reproduce the mechanism: a raw loop (:func:`raw_train_loop`, the
"Torch" path) vs :class:`Trainer` (hooks before/after every batch, logging
callbacks with configurable frequency/cost).

Both paths share the jitted step, the ConcurrentDataLoader and the device
prefetch ring, and record the paper's span lanes so Table-3 style stats come
out of the same tracer.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax

from repro.core.prefetch import DevicePrefetchRing
from repro.core.tracing import (
    NULL_TRACER,
    RUN_TRAINING_BATCH,
    Tracer,
)
from repro.core.utilization import recent_busy_fraction


class Callback:
    def on_train_start(self, trainer: "Trainer") -> None: ...
    def on_epoch_start(self, trainer: "Trainer", epoch: int) -> None: ...
    def on_train_batch_start(self, trainer: "Trainer", batch: Any, idx: int) -> None: ...
    def on_train_batch_end(self, trainer: "Trainer", metrics: Dict, idx: int) -> None: ...
    def on_epoch_end(self, trainer: "Trainer", epoch: int) -> None: ...
    def on_train_end(self, trainer: "Trainer") -> None: ...


class LoggingCallback(Callback):
    """Emulates the paper's GPUStatsMonitor-style logger: every call burns
    ``cost_s`` of host time (the 'slightly too aggressive logging')."""

    def __init__(self, log_every_n_steps: int = 10, cost_s: float = 0.0,
                 sink: Optional[Callable[[str], None]] = None) -> None:
        self.every = max(log_every_n_steps, 1)
        self.cost_s = cost_s
        self.sink = sink or (lambda s: None)
        self.lines: List[str] = []

    def on_train_batch_end(self, trainer, metrics, idx) -> None:
        if idx % self.every == 0:
            if self.cost_s:
                time.sleep(self.cost_s)
            line = f"step={trainer.global_step} " + " ".join(
                f"{k}={float(v):.4f}" for k, v in metrics.items()
            )
            self.lines.append(line)
            self.sink(line)


class CheckpointCallback(Callback):
    def __init__(self, manager, every_steps: int, loader=None, blocking: bool = False):
        self.manager = manager
        self.every = every_steps
        self.loader = loader
        self.blocking = blocking

    def on_train_batch_end(self, trainer, metrics, idx) -> None:
        if self.every and trainer.global_step % self.every == 0:
            extra = {}
            if self.loader is not None:
                # Cursor derived from the TRAINER's position, not the
                # loader's: the device prefetch ring consumes batches ahead
                # of the training step, so loader.state_dict() would skip
                # the in-flight batches on restart.  One step == one batch.
                n = len(self.loader)
                extra = {"loader": {
                    "epoch": trainer.global_step // n,
                    "next_batch": trainer.global_step % n,
                }}
            self.manager.save(
                trainer.global_step, trainer.state, extra_meta=extra,
                blocking=self.blocking,
            )


@dataclass
class TrainResult:
    steps: int
    epochs: int
    wall_s: float
    last_metrics: Dict[str, float] = field(default_factory=dict)
    history: List[Dict[str, float]] = field(default_factory=list)


def _make_ring(loader, depth: int, tracer, ingest_fn=None) -> DevicePrefetchRing:
    """Build the per-epoch device prefetch ring; when the loader carries an
    autotuner, register the ring's depth as a live knob (sized so it has
    headroom up to the configured bound) and wire the accelerator-utilization
    signal so the controller stops buying loader throughput the training step
    can't eat (AutotuneConfig.util_gate)."""
    auto = getattr(loader, "autotuner", None)
    max_depth = depth
    if auto is not None:
        max_depth = max(depth, auto.cfg.max_device_prefetch)
    ring = DevicePrefetchRing(
        iter(loader), depth=depth, max_depth=max_depth,
        # sharded delivery hands over device-resident global arrays; the
        # ring then only paces (a device_put would gather them back)
        transfer=not getattr(loader, "delivers_device_batches", False),
        tracer=tracer,
        # on-device epilogue for epilogue="device" datasets: runs the fused
        # ingest_norm cast+normalize right after the put, off the host
        ingest_fn=ingest_fn,
    )
    if auto is not None:
        # iter(loader) above re-bound the loader knobs; the ring knob rides
        # along for this epoch and is dropped at the next re-bind
        auto.attach_ring(ring)
        if tracer is not NULL_TRACER and auto.util_fn is None:
            auto.util_fn = lambda: recent_busy_fraction(tracer)
    note = getattr(loader, "note_device_ring", None)
    if callable(note):
        # the ring is the staged pipeline's final (device-prefetch) stage;
        # registering it folds its depth into loader.stage_stats()
        note(ring)
    return ring


def _release_coordination(loader) -> None:
    """End-of-fit courtesy for multi-host runs: hand back any held up-probe
    lease so co-located hosts don't wait out the crash TTL before climbing."""
    release = getattr(loader, "release_coordination", None)
    if callable(release):
        release()


class Trainer:
    def __init__(
        self,
        train_step: Callable,
        state: Any,
        *,
        callbacks: Optional[List[Callback]] = None,
        tracer: Tracer = NULL_TRACER,
        device_prefetch: int = 2,
        jit: bool = True,
        donate: bool = True,
        ingest_fn: Optional[Callable] = None,
    ) -> None:
        self.train_step = (
            jax.jit(train_step, donate_argnums=(0,)) if jit and donate
            else jax.jit(train_step) if jit
            else train_step
        )
        self.state = state
        self.callbacks = callbacks or []
        self.tracer = tracer
        self.device_prefetch = device_prefetch
        # dict -> dict device-side batch epilogue (see
        # repro.kernels.ingest_norm.ops.make_ingest_fn); None = host epilogue
        self.ingest_fn = ingest_fn
        self.global_step = 0

    def _hook(self, name: str, *args) -> None:
        for cb in self.callbacks:
            getattr(cb, name)(self, *args)

    def fit(
        self,
        loader: Iterable,
        epochs: int = 1,
        max_steps: Optional[int] = None,
        start_epoch: int = 0,
    ) -> TrainResult:
        t0 = time.time()
        self._hook("on_train_start")
        history: List[Dict[str, float]] = []
        metrics: Dict[str, float] = {}
        done = False
        for epoch in range(start_epoch, epochs):
            if hasattr(loader, "set_epoch") and epoch != start_epoch:
                loader.set_epoch(epoch)
            self._hook("on_epoch_start", epoch)
            ring = _make_ring(loader, self.device_prefetch, self.tracer,
                              ingest_fn=self.ingest_fn)
            for i, batch in enumerate(ring):
                self._hook("on_train_batch_start", batch, i)
                with self.tracer.span(RUN_TRAINING_BATCH, step=self.global_step):
                    self.state, m = self.train_step(self.state, batch)
                    m = jax.tree.map(float, jax.device_get(m))
                self.global_step += 1
                metrics = m
                history.append(m)
                self._hook("on_train_batch_end", m, i)
                if max_steps is not None and self.global_step >= max_steps:
                    done = True
                    break
            ring.close()
            self._hook("on_epoch_end", epoch)
            if done:
                break
        self._hook("on_train_end")
        _release_coordination(loader)
        return TrainResult(
            steps=self.global_step,
            epochs=epoch + 1,
            wall_s=time.time() - t0,
            last_metrics=metrics,
            history=history,
        )


def raw_train_loop(
    train_step: Callable,
    state: Any,
    loader: Iterable,
    *,
    epochs: int = 1,
    max_steps: Optional[int] = None,
    tracer: Tracer = NULL_TRACER,
    device_prefetch: int = 2,
    jit: bool = True,
    ingest_fn: Optional[Callable] = None,
) -> TrainResult:
    """The 'pure Torch' path: no hooks, no callbacks, same jitted step.
    Pass ``jit=False`` when ``train_step`` is already jitted (lets callers
    share one compiled executable across runs)."""
    step_fn = jax.jit(train_step, donate_argnums=(0,)) if jit else train_step
    t0 = time.time()
    steps = 0
    metrics: Dict[str, float] = {}
    history = []
    for epoch in range(epochs):
        if hasattr(loader, "set_epoch") and epoch:
            loader.set_epoch(epoch)
        ring = _make_ring(loader, device_prefetch, tracer, ingest_fn=ingest_fn)
        for batch in ring:
            with tracer.span(RUN_TRAINING_BATCH, step=steps):
                state, m = step_fn(state, batch)
                metrics = jax.tree.map(float, jax.device_get(m))
            history.append(metrics)
            steps += 1
            if max_steps is not None and steps >= max_steps:
                ring.close()
                _release_coordination(loader)
                return TrainResult(steps, epoch + 1, time.time() - t0, metrics, history)
        ring.close()
    _release_coordination(loader)
    return TrainResult(steps, epochs, time.time() - t0, metrics, history)
