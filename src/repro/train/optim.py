"""Optimizers (AdamW / Adafactor / SGD-momentum) + LR schedules.

Optax-style pure functions but dependency-free.  Adafactor (factored second
moments, no momentum) is the fit-enabler for the 340B config: ~4 bytes/param
of optimizer state instead of AdamW's 8.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, opt_state, params, step) -> (new_params, new_opt_state)


def make_schedule(cfg: TrainConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    base, warm, total = cfg.learning_rate, cfg.warmup_steps, cfg.total_steps

    def sched(step):
        step = step.astype(jnp.float32)
        warm_lr = base * jnp.minimum(1.0, (step + 1) / max(warm, 1))
        if cfg.schedule == "constant":
            return warm_lr
        frac = jnp.clip((step - warm) / max(total - warm, 1), 0.0, 1.0)
        if cfg.schedule == "linear":
            return warm_lr * (1.0 - frac)
        return warm_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))  # cosine

    return sched


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def make_adamw(cfg: TrainConfig) -> Optimizer:
    sched = make_schedule(cfg)
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"mu": jax.tree.map(zeros, params), "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        if cfg.grad_clip:
            grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        lr = sched(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            step_ = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = jax.tree.leaves(state["mu"])
        flat_v = jax.tree.leaves(state["nu"])
        flat_p = jax.tree.leaves(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"mu": new_m, "nu": new_v}

    return Optimizer(init, update)


def make_adafactor(cfg: TrainConfig) -> Optimizer:
    """Factored Adafactor (Shazeer & Stern): row/col second moments for >=2D
    tensors (factored over the last two dims), full for 1D.  No momentum."""
    sched = make_schedule(cfg)
    eps1, eps2 = 1e-30, 1e-3
    wd = cfg.weight_decay

    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {"v": jax.tree.map(st, params, is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(grads, state, params, step):
        if cfg.grad_clip:
            grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        lr = sched(step)
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-0.8)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps1
            if p.ndim >= 2:
                vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(-2)
                denom = vr[..., None] * vc[..., None, :] / jnp.maximum(
                    vr.mean(-1, keepdims=True)[..., None], eps1
                )
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps1))
                nv = {"vr": vr, "vc": vc}
            else:
                nv_ = beta2 * v["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(nv_, eps1))
                nv = {"v": nv_}
            # update clipping (RMS <= 1)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms)
            pf = p.astype(jnp.float32)
            scale = jnp.maximum(jnp.sqrt(jnp.mean(pf * pf)), eps2)
            newp = pf - lr * scale * u - lr * wd * pf
            return newp.astype(p.dtype), nv

        flat_g, tdef = jax.tree.flatten(grads)
        flat_p = jax.tree.leaves(params)
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        return tdef.unflatten([o[0] for o in out]), {
            "v": tdef.unflatten([o[1] for o in out])
        }

    return Optimizer(init, update)


def make_sgd(cfg: TrainConfig) -> Optimizer:
    sched = make_schedule(cfg)
    momentum = cfg.beta1

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        if cfg.grad_clip:
            grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
        lr = sched(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32) + cfg.weight_decay * p.astype(jnp.float32)
            m = momentum * m + g
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        flat_g, tdef = jax.tree.flatten(grads)
        out = [
            upd(g, m, p)
            for g, m, p in zip(flat_g, jax.tree.leaves(state["m"]), jax.tree.leaves(params))
        ]
        return tdef.unflatten([o[0] for o in out]), {"m": tdef.unflatten([o[1] for o in out])}

    return Optimizer(init, update)


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    if cfg.optimizer == "adamw":
        return make_adamw(cfg)
    if cfg.optimizer == "adafactor":
        return make_adafactor(cfg)
    if cfg.optimizer == "sgd":
        return make_sgd(cfg)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")
