"""Gradient compression for the DP all-reduce (distributed-optimization trick).

Models the wire format of the gradient reduction: ``bf16`` halves collective
bytes; ``int8_ef`` quarters them with per-tensor scaling + error feedback
(the quantization residual is carried to the next step, so the scheme is
unbiased in the long run).  The compress/decompress pair wraps the gradients
inside the jitted train step — on a real mesh XLA reduces the *compressed*
representation; here correctness properties are what we test.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def compress_bf16(grads: Any) -> Any:
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads: Any) -> Any:
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def apply_compression(
    grads: Any, ef: Optional[Any], mode: str
) -> Tuple[Any, Optional[Any]]:
    """Returns (effective grads after the simulated wire round-trip, new ef)."""
    if mode == "none":
        return grads, ef
    if mode == "bf16":
        return decompress_bf16(compress_bf16(grads)), ef
    if mode == "int8_ef":
        assert ef is not None, "int8_ef requires error-feedback state"

        def one(g, e):
            g = g.astype(jnp.float32) + e
            q, s = compress_int8(g)
            deq = decompress_int8(q, s)
            return deq, g - deq

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return tdef.unflatten([o[0] for o in outs]), tdef.unflatten([o[1] for o in outs])
    raise ValueError(f"unknown compression mode {mode!r}")
