"""Jitted train/eval steps with grad-accumulation and compression hooks.

``make_train_step(model_cfg, train_cfg)`` builds::

    train_step(state, batch) -> (state, metrics)

* loss = model loss + MoE aux loss
* grad accumulation: ``lax.scan`` over ``microbatches`` leading-dim splits,
  accumulating fp32 grads (bounds activation memory for the 340B/52B cells)
* optional gradient compression round-trip (bf16 / int8+error-feedback)
* optimizer update (AdamW / Adafactor / SGD)

State is a plain dict pytree => trivially shardable and checkpointable.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models import encdec, resnet, transformer
from repro.train import compression
from repro.train.optim import global_norm, make_optimizer


def loss_fn_for(cfg: ModelConfig) -> Callable:
    if cfg.family == "encdec":
        return lambda p, b: encdec.forward_train(p, b, cfg)
    if cfg.family == "resnet":
        raise ValueError("use make_resnet_train_step for the resnet family")
    return lambda p, b: transformer.forward_train(p, b, cfg)


def init_params_for(cfg: ModelConfig, key) -> Any:
    if cfg.family == "encdec":
        return encdec.init_encdec(key, cfg)
    if cfg.family == "resnet":
        return resnet.init_resnet(key, cfg)[0]
    return transformer.init_lm(key, cfg)


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> Dict[str, Any]:
    params = init_params_for(cfg, key)
    opt = make_optimizer(tcfg)
    state = {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if tcfg.grad_compression == "int8_ef":
        state["ef"] = compression.init_error_feedback(params)
    return state


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    opt = make_optimizer(tcfg)
    loss_fn = loss_fn_for(cfg)
    M = max(tcfg.microbatches, 1)

    def compute_grads(params, batch):
        def total_loss(p, b):
            loss, aux = loss_fn(p, b)
            return loss + aux, (loss, aux)

        if M == 1:
            (tl, (loss, aux)), grads = jax.value_and_grad(total_loss, has_aux=True)(
                params, batch
            )
            return grads, loss, aux

        def micro(b):
            return jax.tree.map(lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]), b)

        mbatch = micro(batch)

        def body(carry, mb):
            acc, lsum, asum = carry
            (tl, (loss, aux)), g = jax.value_and_grad(total_loss, has_aux=True)(
                params, mb
            )
            acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc, g)
            return (acc, lsum + loss, asum + aux), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum, asum), _ = jax.lax.scan(
            body, (zero, jnp.zeros(()), jnp.zeros(())), mbatch
        )
        grads = jax.tree.map(lambda g: g / M, gsum)
        return grads, lsum / M, asum / M

    def train_step(state, batch):
        params = state["params"]
        grads, loss, aux = compute_grads(params, batch)
        ef = state.get("ef")
        grads, new_ef = compression.apply_compression(grads, ef, tcfg.grad_compression)
        gnorm = global_norm(grads)
        new_params, new_opt = opt.update(grads, state["opt"], params, state["step"])
        new_state = dict(
            state, params=new_params, opt=new_opt, step=state["step"] + 1
        )
        if new_ef is not None:
            new_state["ef"] = new_ef
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm}
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    loss_fn = loss_fn_for(cfg)

    def eval_step(params, batch):
        loss, aux = loss_fn(params, batch)
        return {"loss": loss, "aux_loss": aux}

    return eval_step


# -- resnet (BatchNorm state threads through) --------------------------------


def init_resnet_train_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> Dict[str, Any]:
    params, bn = resnet.init_resnet(key, cfg)
    opt = make_optimizer(tcfg)
    return {
        "params": params,
        "bn": bn,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_resnet_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    opt = make_optimizer(tcfg)

    def train_step(state, batch):
        def loss_fn(p):
            loss, (new_bn, acc) = resnet.resnet_loss(p, state["bn"], batch, cfg, train=True)
            return loss, (new_bn, acc)

        (loss, (new_bn, acc)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"]
        )
        gnorm = global_norm(grads)
        new_params, new_opt = opt.update(grads, state["opt"], state["params"], state["step"])
        new_state = dict(
            state, params=new_params, bn=new_bn, opt=new_opt, step=state["step"] + 1
        )
        return new_state, {"loss": loss, "accuracy": acc, "grad_norm": gnorm}

    return train_step
