"""Fault-tolerance runtime pieces (1000+-node posture).

* :class:`HeartbeatMonitor` — tracks liveness of participants; a host whose
  heartbeat is older than ``timeout`` is declared dead.  On a real cluster
  each host POSTs to the coordinator; here it is driven in-process (tested).
* :func:`elastic_plan` — pure function (num_items, alive_hosts) -> shard map;
  on membership change every survivor recomputes its slice with no
  coordination and no data loss (paired with ``sampler.shard_plan``).
* :class:`RestartPolicy` — crash/restore loop helper: restore latest
  checkpoint, fast-forward the loader, resume (used by launch/train.py).

Straggler mitigation at the *data layer* (hedged GETs) lives in
``core.fetcher``; at the *step* layer stragglers are absorbed by the bounded
prefetch queue.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.sampler import shard_plan


class HeartbeatMonitor:
    def __init__(self, hosts: Sequence[int], timeout_s: float = 30.0) -> None:
        self.timeout_s = timeout_s
        self._last: Dict[int, float] = {h: time.monotonic() for h in hosts}

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self._last[host] = time.monotonic() if now is None else now

    def alive(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items() if now - t <= self.timeout_s)

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items() if now - t > self.timeout_s)


def elastic_plan(global_batch: Sequence[int], alive_hosts: Sequence[int]) -> Dict[int, List[int]]:
    """Re-partition a global batch over the currently-alive hosts.

    Rank r of host h = index of h in the sorted alive list: the plan is a
    pure function of (batch, membership) — every survivor computes the same
    answer independently.
    """
    alive = sorted(alive_hosts)
    n = len(alive)
    return {h: shard_plan(global_batch, r, n) for r, h in enumerate(alive)}


@dataclass
class RestartPolicy:
    """Resume-from-latest with bounded retries (driver-side crash loop)."""

    max_restarts: int = 3
    backoff_s: float = 1.0
    restarts: int = 0

    def should_restart(self) -> bool:
        return self.restarts < self.max_restarts

    def on_failure(self) -> float:
        """Returns the backoff to sleep; raises if the budget is exhausted."""
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError(f"exceeded {self.max_restarts} restarts")
        return self.backoff_s * (2 ** (self.restarts - 1))
