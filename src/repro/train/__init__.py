"""Training substrate: optimizers, steps, checkpointing, fault tolerance."""
